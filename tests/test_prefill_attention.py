"""Fused chunked-prefill attention: the backend ``prefill_attention``
primitive must agree with the masked-einsum oracle on every backend —
bitwise on ``xla`` (it IS the einsum), within f32 tolerance on ``ref`` (the
Pallas cache-continuation kernel in interpret mode) — and must be
*chunk-invariant*: splitting a prompt into ragged chunks (primes, 1-token
tails, window-bucket crossings) may not move one bit of any logit, which is
the property the engine's token-identity contract now rests on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.kernels import ops
from repro.kernels.backend import available, get_backend, set_backend
from repro.kernels.prefill_attention import prefill_attention_pallas
from repro.models import attention as A
from repro.models import lm
from repro.serving import Engine, Request, SchedulerConfig, serial_decode
from repro.sharding.ctx import default_ctx

B, HQ, HKV, HD = 3, 8, 4, 32
BLOCK = 16


def _cache(key, max_seq, quantized):
    ks = jax.random.split(key, 4)
    if quantized:
        return {
            "k_q": jax.random.randint(ks[0], (B, max_seq, HKV, HD),
                                      -127, 128, jnp.int8),
            "v_q": jax.random.randint(ks[1], (B, max_seq, HKV, HD),
                                      -127, 128, jnp.int8),
            "k_s": jax.random.uniform(ks[2], (B, max_seq, HKV),
                                      jnp.float32, 0.01, 0.1),
            "v_s": jax.random.uniform(ks[3], (B, max_seq, HKV),
                                      jnp.float32, 0.01, 0.1),
        }
    return {"k": jax.random.normal(ks[0], (B, max_seq, HKV, HD),
                                   jnp.bfloat16),
            "v": jax.random.normal(ks[1], (B, max_seq, HKV, HD),
                                   jnp.bfloat16)}


def _kernel_args(cache):
    if "k_q" in cache:
        return (cache["k_q"], cache["v_q"], cache["k_s"], cache["v_s"])
    return (cache["k"], cache["v"], None, None)


# ------------------------------------------------------------ kernel oracle
@pytest.mark.parametrize("sq,bq,bk", [(1, 16, 16), (5, 8, 16), (16, 8, 64),
                                      (17, 16, 16)])
@pytest.mark.parametrize("per_slot", [False, True])
@pytest.mark.parametrize("quantized", [False, True])
def test_prefill_kernel_ref_vs_einsum(quantized, per_slot, sq, bq, bk):
    """Pallas cache-continuation kernel (interpret mode) vs the einsum
    oracle, f32 tolerance: exercises ragged query tiles (sq not a bq
    multiple), the per-slot block skip, the KV-tail padding mask (max_seq
    not a bk multiple), and the fused INT8 dequant epilogue."""
    max_seq = 80                       # not a multiple of 64: padded KV tail
    key = jax.random.PRNGKey(sq * 31 + bq)
    cache = _cache(key, max_seq, quantized)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, sq, HQ, HD),
                          jnp.bfloat16)
    hi = max_seq - sq
    start = (jnp.asarray([0, hi // 2, hi], jnp.int32) if per_slot
             else jnp.full((B,), hi // 2, jnp.int32))
    oracle = A.cached_attention(q, cache, start)
    out = prefill_attention_pallas(q, *_kernel_args(cache), start,
                                   bq=bq, bk=bk, interpret=True)
    # int8 path: the oracle rounds probabilities AND dequantized V to bf16
    # before its dot while the kernel accumulates f32 — values span ~±12
    # (127 * 0.1 scale), so bf16 rounding alone is ~0.1 absolute
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32),
                               rtol=3e-2, atol=1.5e-1 if quantized else 3e-2)


@pytest.mark.parametrize("sq", [1, 7])
@pytest.mark.parametrize("quantized", [False, True])
def test_prefill_attention_xla_bitwise_vs_einsum(quantized, sq):
    """The xla backend's prefill primitive is literally the masked einsum —
    bitwise, windowed or not. Token identity between engine chunked prefill
    and serial whole-prompt prefill hinges on this on the xla backend."""
    max_seq = 64
    key = jax.random.PRNGKey(sq)
    cache = _cache(key, max_seq, quantized)
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, sq, HQ, HD),
                          jnp.bfloat16)
    start = jnp.asarray([1, 9, 24], jnp.int32)
    oracle = A.cached_attention(q, cache, start)
    win = -(-(24 + sq) // BLOCK) * BLOCK
    prev = set_backend("xla")
    try:
        for window in (None, win):
            out = ops.prefill_attention(q, cache, start, window=window)
            np.testing.assert_array_equal(np.asarray(oracle, np.float32),
                                          np.asarray(out, np.float32))
    finally:
        set_backend(prev)


@pytest.mark.parametrize("quantized", [False, True])
def test_prefill_kernel_chunk_invariant_bitwise(quantized):
    """Splitting Sq=13 queries into ragged chunks (5, 7, 1-token tail) and
    widening the visible window must reproduce the whole-chunk kernel output
    BIT-FOR-BIT: causal limits are absolute positions, so chunk boundaries,
    query-tile sizes, and trailing masked KV blocks are all exact no-ops."""
    max_seq, sq = 48, 13
    key = jax.random.PRNGKey(3)
    cache = _cache(key, max_seq, quantized)
    args = _kernel_args(cache)
    q = jax.random.normal(jax.random.fold_in(key, 4), (B, sq, HQ, HD),
                          jnp.bfloat16)
    start0 = jnp.zeros((B,), jnp.int32)
    whole = prefill_attention_pallas(q, *args, start0, bq=8, bk=16,
                                     interpret=True)
    parts = []
    for lo, hi in [(0, 5), (5, 12), (12, 13)]:
        parts.append(prefill_attention_pallas(
            q[:, lo:hi], *args, jnp.full((B,), lo, jnp.int32),
            bq=8, bk=16, interpret=True))
    np.testing.assert_array_equal(
        np.asarray(whole, np.float32),
        np.asarray(jnp.concatenate(parts, axis=1), np.float32))
    # a wider window (more trailing KV blocks) may not move a bit either
    sl = lambda t, n: None if t is None else t[:, :n]
    narrow = prefill_attention_pallas(
        q, args[0][:, :32], args[1][:, :32], sl(args[2], 32), sl(args[3], 32),
        start0, bq=8, bk=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(whole, np.float32),
                                  np.asarray(narrow, np.float32))


def test_prefill_attention_registered_on_all_backends():
    """Every registered backend exposes the prefill primitive; every backend
    executable on this platform produces a finite, well-shaped result
    agreeing with `xla` within f32 tolerance."""
    assert set(available()) == {"pallas", "xla", "ref"}
    for name in available():
        assert callable(get_backend(name).prefill_attention)
    key = jax.random.PRNGKey(9)
    cache = _cache(key, 32, False)
    q = jax.random.normal(key, (B, 5, HQ, HD), jnp.bfloat16)
    start = jnp.asarray([0, 5, 27], jnp.int32)
    run = ["xla", "ref"] + (["pallas"] if jax.default_backend() == "tpu"
                            else [])
    outs = {}
    for name in run:
        prev = set_backend(name)
        try:
            outs[name] = np.asarray(
                ops.prefill_attention(q, cache, start), np.float32)
        finally:
            set_backend(prev)
        assert outs[name].shape == (B, 5, HQ, HD)
        assert np.all(np.isfinite(outs[name]))
        np.testing.assert_allclose(outs[name], outs["xla"],
                                   rtol=3e-2, atol=3e-2)


# --------------------------------------------------- engine token identity
@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _identity_sweep(cfg, params, lens, quantized, prefill_chunk,
                    max_new=4, max_seq=64, seed=0):
    """Engine output must equal serial decode token-for-token for every
    prompt length in ``lens`` (run as one staggered batch)."""
    ctx = dataclasses.replace(default_ctx(), quantized_kv=quantized)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]
    eng = Engine(params, cfg, ctx=ctx, n_slots=2, max_seq=max_seq,
                 sched=SchedulerConfig(prefill_chunk=prefill_chunk))
    res = eng.run([Request(prompt=p, max_new_tokens=max_new)
                   for p in prompts],
                  arrival_ticks=[2 * i for i in range(len(prompts))])
    for i, p in enumerate(prompts):
        ref = serial_decode(params, cfg, p, max_new, ctx=ctx,
                            max_seq=max_seq)
        assert res[i].tokens == ref, (lens[i], res[i].tokens, ref)


@pytest.mark.parametrize("quantized", [False, True])
def test_engine_chunked_prefill_token_identity_ragged(setup, quantized):
    """Deterministic corner sweep on the session backend (the CI matrix
    runs it under xla AND ref): prime prompt lengths, a 1-token tail chunk
    (16 = 3*5 + 1), and prompts crossing the window_block=16 boundary
    (17, 31) — all bit-identical to serial whole-prompt decode with the
    prefill primitive active."""
    cfg, params = setup
    _identity_sweep(cfg, params, lens=[13, 16, 17, 31], quantized=quantized,
                    prefill_chunk=5)


@given(lens=st.lists(st.integers(1, 40), min_size=1, max_size=3),
       chunk=st.integers(1, 9), quantized=st.booleans())
@settings(max_examples=6, deadline=None)
def test_engine_prefill_token_identity_property(lens, chunk, quantized):
    """Property sweep: ANY ragged prompt lengths × chunk size × KV dtype
    keep engine output == serial decode bit-for-bit."""
    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    _identity_sweep(cfg, params, lens=lens, quantized=quantized,
                    prefill_chunk=chunk, seed=sum(lens) + chunk)
