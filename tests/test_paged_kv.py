"""Paged KV cache: page-table indirection, prefix reuse, and numerics.

Load-bearing guarantees:
  * the paged engine is TOKEN-IDENTICAL to serial decode at every page
    size — multi-page (16), mid (32), and the ``page_size == max_seq``
    degenerate (contiguous-identity) case — for greedy AND seeded
    sampling, ragged prompts, INT8 KV, and speculative decoding with
    pos-only rollback;
  * a repeated system prompt hits the hash-keyed prefix cache (copy-free
    page mapping; only the tail prefills) and every page is refcounted:
    eviction releases exactly the slot's references, the arena never
    leaks, the trash page stays pinned (hypothesis property over random
    alloc/ref/unref interleavings);
  * ``reset_slot`` in paged mode never touches the shared KV arena
    (recurrent state + pos only) — scrubbing it would corrupt pages other
    slots still reference;
  * ``scripts/check_bench.py`` gates paged throughput parity and the
    shared-prefix memory ceiling by NAME.
"""
import dataclasses
import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.models import lm
from repro.serving import (Engine, Request, SamplingConfig, SchedulerConfig,
                           serial_decode)
from repro.serving import state_pool as sp
from repro.sharding.ctx import default_ctx

ARCH = "qwen3-0.6b"
MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]


def _assert_drained(eng):
    """After a run every slot is evicted: the only live references left
    are the prefix cache's, and the allocator invariants hold."""
    cache_pages = (len({p for v in eng.prefix._entries.values() for p in v})
                   if eng.prefix is not None else 0)
    assert eng.alloc.pages_in_use == cache_pages
    eng.alloc.check()


# ------------------------------------------------------- engine == serial
def test_paged_token_identical_every_page_size(setup):
    """Ragged prompts through multi-page, mid, and degenerate
    (page_size == max_seq) layouts — all three must reproduce the serial
    tokens bit-for-bit; the degenerate case is the contiguous-identity
    anchor."""
    cfg, params = setup
    prompts = _prompts(cfg, [13, 7, 18], seed=2)
    refs = [serial_decode(params, cfg, p, 6, max_seq=MAX_SEQ)
            for p in prompts]
    for ps in (16, 32, MAX_SEQ):
        eng = Engine(params, cfg, n_slots=3, max_seq=MAX_SEQ,
                     sched=SchedulerConfig(prefill_chunk=5), page_size=ps)
        res = eng.run([Request(prompt=p, max_new_tokens=6) for p in prompts])
        for i in range(len(prompts)):
            assert res[i].tokens == refs[i], f"page_size={ps} prompt {i}"
        _assert_drained(eng)


def test_paged_seeded_sampling_matches_serial(setup):
    """Sampling draws with position-derived keys, so paging (which never
    changes logical positions) must not perturb a seeded trace."""
    cfg, params = setup
    scfg = SamplingConfig(temperature=0.8, top_k=8, seed=7)
    prompts = _prompts(cfg, [9, 14], seed=3)
    refs = [serial_decode(params, cfg, p, 5, max_seq=MAX_SEQ, sampling=scfg)
            for p in prompts]
    eng = Engine(params, cfg, n_slots=2, max_seq=MAX_SEQ,
                 sched=SchedulerConfig(prefill_chunk=6), page_size=16,
                 sampling=scfg)
    res = eng.run([Request(prompt=p, max_new_tokens=5) for p in prompts])
    for i in range(len(prompts)):
        assert res[i].tokens == refs[i]
    _assert_drained(eng)


def test_paged_int8_kv_token_identical(setup):
    """INT8 KV quantizes per token at write time, so the paged gather must
    dequantize the same bits the contiguous path would."""
    cfg, params = setup
    from repro.compress import compress
    art = compress(params, cfg, log=lambda s: None)
    ctx_q = dataclasses.replace(default_ctx(), quantized_kv=True)
    prompts = _prompts(cfg, [11, 17], seed=4)
    refs = [serial_decode(art.params, cfg, p, 5, ctx=ctx_q, max_seq=MAX_SEQ)
            for p in prompts]
    eng = Engine(art.params, cfg, ctx=ctx_q, n_slots=2, max_seq=MAX_SEQ,
                 sched=SchedulerConfig(prefill_chunk=8), page_size=16)
    res = eng.run([Request(prompt=p, max_new_tokens=5) for p in prompts])
    for i in range(len(prompts)):
        assert res[i].tokens == refs[i]
    _assert_drained(eng)


def test_paged_speculative_rollback_token_identical(setup):
    """Speculative decode over paged pools: draft and verify arenas share
    ONE table, rejection rolls back by pos only (pages stay mapped), and
    greedy output still equals serial bf16."""
    cfg, params = setup
    from repro.compress import compress
    art = compress(params, cfg, log=lambda s: None)
    ctx_q = dataclasses.replace(default_ctx(), quantized_kv=True)
    prompts = _prompts(cfg, [13, 7], seed=2)
    refs = [serial_decode(params, cfg, p, 6, max_seq=MAX_SEQ)
            for p in prompts]
    for ps in (16, MAX_SEQ):
        eng = Engine(params, cfg, n_slots=2, max_seq=MAX_SEQ,
                     sched=SchedulerConfig(prefill_chunk=8),
                     draft_params=art.params, spec_k=3, draft_ctx=ctx_q,
                     page_size=ps)
        res = eng.run([Request(prompt=p, max_new_tokens=6) for p in prompts])
        for i in range(len(prompts)):
            assert res[i].tokens == refs[i], f"page_size={ps} prompt {i}"
        assert eng.stats["drafted_tokens"] > 0
        _assert_drained(eng)


# --------------------------------------------------------- prefix sharing
def test_prefix_reuse_skips_prefill_and_stays_identical(setup):
    """Requests repeating a page-aligned system prompt: later admissions
    map the cached pages copy-free (>= 1 hit each once the cache is warm),
    prefill only covers the tails, and the tokens still match serial."""
    cfg, params = setup
    rng = np.random.RandomState(5)
    head = rng.randint(0, cfg.vocab_size, 32).tolist()
    reqs = [Request(prompt=head + rng.randint(0, cfg.vocab_size, 5).tolist(),
                    max_new_tokens=4) for _ in range(4)]
    eng = Engine(params, cfg, n_slots=2, max_seq=MAX_SEQ,
                 sched=SchedulerConfig(prefill_chunk=8), page_size=16)
    res = eng.run(reqs)
    for i, r in enumerate(reqs):
        ref = serial_decode(params, cfg, r.prompt, 4, max_seq=MAX_SEQ)
        assert res[i].tokens == ref, f"request {i}"
    st_ = eng.stats
    # 2 slots admit the first two requests before either inserts, so the
    # floor is hits on every LATER admission, not all four
    assert st_["prefix_hits"] >= 2
    assert st_["prefix_hit_tokens"] >= 2 * 32
    assert st_["bytes_saved"] > 0
    assert st_["prefill_tokens"] < sum(len(r.prompt) for r in reqs)
    assert st_["pages_peak"] <= eng.total_pages - 1
    _assert_drained(eng)


def test_prefix_cache_disabled_still_identical(setup):
    cfg, params = setup
    rng = np.random.RandomState(6)
    head = rng.randint(0, cfg.vocab_size, 16).tolist()
    reqs = [Request(prompt=head + rng.randint(0, cfg.vocab_size, 3).tolist(),
                    max_new_tokens=3) for _ in range(2)]
    eng = Engine(params, cfg, n_slots=2, max_seq=MAX_SEQ,
                 sched=SchedulerConfig(prefill_chunk=8), page_size=16,
                 prefix_cache=False)
    res = eng.run(reqs)
    for i, r in enumerate(reqs):
        ref = serial_decode(params, cfg, r.prompt, 3, max_seq=MAX_SEQ)
        assert res[i].tokens == ref
    assert eng.stats["prefix_hits"] == 0
    assert eng.alloc.pages_in_use == 0      # nothing retained
    eng.alloc.check()


# ------------------------------------------------- allocator / cache units
def test_page_allocator_exhaustion_and_reuse():
    alloc = sp.PageAllocator(5)              # trash + 4 usable
    a = alloc.alloc(4)
    assert sorted(a) == [1, 2, 3, 4] and alloc.free_pages == 0
    with pytest.raises(MemoryError):
        alloc.alloc(1)
    alloc.unref([a[0]])
    assert alloc.alloc(1) == [a[0]]          # freed page comes back
    alloc.check()


def test_prefix_cache_longest_aligned_proper_prefix():
    alloc = sp.PageAllocator(9)
    cache = sp.PrefixCache(alloc, page_size=4)
    prompt = np.arange(12, dtype=np.int32)
    pages = alloc.alloc(3)
    assert cache.insert(prompt, pages, 12) == 12
    # exact repeat: hit caps at 8 tokens (align_down(12-1, 4)) so one
    # prompt token always prefills
    hit, got = cache.lookup(prompt)
    assert hit == 8 and got == pages[:2]
    alloc.unref(got)
    # longer prompt with the same head: full 12-token entry hits
    hit, got = cache.lookup(np.arange(14, dtype=np.int32))
    assert hit == 12 and got == pages
    alloc.unref(got)
    # diverging head: miss
    assert cache.lookup(np.full(12, 99, np.int32)) == (0, [])
    cache.clear()
    alloc.unref(pages)
    assert alloc.pages_in_use == 0
    alloc.check()


def test_prefix_cache_lru_eviction_unrefs():
    alloc = sp.PageAllocator(9)
    cache = sp.PrefixCache(alloc, page_size=4)
    p1 = alloc.alloc(1)
    p2 = alloc.alloc(1)
    cache.insert(np.arange(4, dtype=np.int32), p1, 4)
    cache.insert(np.arange(10, 14, dtype=np.int32), p2, 4)
    alloc.unref(p1 + p2)                     # cache holds the only refs
    assert alloc.pages_in_use == 2
    assert cache.evict_lru()                 # drops the p1 entry (oldest)
    assert alloc.refs[p1[0]] == 0 and alloc.refs[p2[0]] == 1
    assert cache.evict_lru() and not cache.evict_lru()
    assert alloc.pages_in_use == 0
    alloc.check()


@settings(deadline=None, max_examples=60)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 4)),
                max_size=40))
def test_page_table_roundtrip_property(ops):
    """Random alloc/ref/unref interleavings (the host-side shape of
    admit -> share -> rollback -> evict): a shadow refcount model must
    agree with the allocator at every step, no page is ever handed out
    while live, and draining all references returns the arena to empty —
    leak-free and double-free-safe by construction."""
    alloc = sp.PageAllocator(9)
    live = {}                                # page -> refs we hold
    for op, n in ops:
        if op == 0:                          # admit: alloc n pages
            try:
                pages = alloc.alloc(n)
            except MemoryError:
                assert alloc.free_pages < n
                continue
            assert not set(pages) & set(live), "live page re-allocated"
            for p in pages:
                live[p] = 1
        elif op == 1 and live:               # share: ref n existing pages
            pages = sorted(live)[:n]
            alloc.ref(pages)
            for p in pages:
                live[p] += 1
        elif op == 2 and live:               # evict/rollback: drop refs
            pages = sorted(live)[:n]
            alloc.unref(pages)
            for p in pages:
                live[p] -= 1
                if live[p] == 0:
                    del live[p]
        assert alloc.pages_in_use == len(live)
        for p, r in live.items():
            assert alloc.refs[p] == r
        alloc.check()
    for p, r in list(live.items()):          # drain
        alloc.unref([p] * r)
    assert alloc.pages_in_use == 0 and alloc.free_pages == 8
    alloc.check()


@settings(deadline=None, max_examples=60)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 3)),
                max_size=30))
def test_prefix_cache_refcount_property(ops):
    """Random insert/lookup/release/evict interleavings of slots against
    the prefix cache — the host-side shape of a cancel/evict storm over
    shared prefixes. Invariants: the allocator's refcount bookkeeping
    stays coherent at every step, a hit always returns a prefix of the
    inserting slot's pages, and releasing every slot ref plus clearing
    the cache returns the arena to empty (cache refs and slot refs never
    get conflated)."""
    ps = 4
    alloc = sp.PageAllocator(12)
    cache = sp.PrefixCache(alloc, page_size=ps)
    inserted = []                            # (prompt_core, pages)
    held = []                                # page lists we hold refs on
    base = 0
    for op, n in ops:
        if op == 0:                          # prefill a fresh prompt + insert
            while True:                      # engine's evict-then-retry loop
                try:
                    pages = alloc.alloc(n)
                    break
                except MemoryError:
                    if not cache.evict_lru():
                        pages = None
                        break
            if pages is None:
                continue
            prompt = np.arange(base, base + n * ps, dtype=np.int32)
            base += n * ps                   # unique tokens => unique keys
            shared = cache.insert(prompt, pages, n * ps)
            assert shared == n * ps
            inserted.append((prompt, pages))
            held.append(pages)               # the slot keeps its own refs
        elif op == 1 and inserted:           # a later request shares a head
            prompt, pages = inserted[n % len(inserted)]
            probe = np.concatenate(
                [prompt, np.full(2, -1, dtype=np.int32)])
            hit_n, hit_pages = cache.lookup(probe)
            if hit_n:                        # LRU may have dropped it
                assert hit_n % ps == 0
                assert hit_pages == list(pages[:hit_n // ps])
                held.append(hit_pages)       # lookup ref'd them for us
        elif op == 2 and held:               # slot finishes / is cancelled
            alloc.unref(held.pop(n % len(held)))
        elif op == 3:                        # arena pressure
            cache.evict_lru()
        alloc.check()
    for pages in held:                       # every slot drains
        alloc.unref(pages)
    cache.clear()
    assert alloc.pages_in_use == 0 and alloc.free_pages == 11
    alloc.check()


# ------------------------------------------------------------- reset_slot
def test_reset_slot_paged_leaves_kv_arena_alone(setup):
    """Admission reset must not write the shared arena: KV leaves come
    back as the SAME buffers (pos=0 makes stale KV unreachable), only
    recurrent state and pos reset."""
    cfg, params = setup
    ctx = default_ctx()
    pool = sp.init_paged_pool(cfg, 2, 32, ctx, params=None,
                              page_size=16, total_pages=5)
    template = sp.init_slot_template(cfg, 32, ctx, params=None)
    out = jax.jit(
        lambda pl: sp.reset_slot(pl, jnp.int32(1), template,
                                 pos0=jnp.int32(3), paged=True),
        donate_argnums=())(pool)
    kv_in = [lf for e in _kv_leaves(pool) for lf in e]
    kv_out = [lf for e in _kv_leaves(out) for lf in e]
    for a, b in zip(kv_in, kv_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(out["pos"][1]) == 3


def _kv_leaves(pool):
    return [jax.tree.leaves(e) for e in pool["caches"] if sp.is_kv_entry(e)]


# -------------------------------------------------------------- check_bench
def _load_check_bench():
    path = (pathlib.Path(__file__).resolve().parents[1] / "scripts"
            / "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench_doc(tmp_path, variants, expected):
    doc = {"schema": "repro-bench/v1",
           "rows": [{"name": "serving/x", "us_per_call": 1.0,
                     "derived": "ok"}],
           "errors": [],
           "serving": {"schema": "repro-bench-serving/v1",
                       "expected_variants": expected,
                       "variants": variants}}
    p = tmp_path / "BENCH_pr.json"
    p.write_text(json.dumps(doc))
    return p


def _variant(**kw):
    v = {"n_requests": 3, "tokens_per_s": 100.0, "latency_p50_ms": 1.0,
         "latency_p95_ms": 2.0, "ttft_p50_ms": 1.0, "ttft_p95_ms": 2.0,
         "param_bytes": 10, "out_tokens": 30}
    v.update(kw)
    return v


def _shared_variant(**kw):
    v = _variant(prefix_hits=6, prefill_tokens=66, prompt_tokens=450,
                 kv_bytes_peak=80, contiguous_kv_bytes=200)
    v.update(kw)
    return v


def test_check_bench_names_missing_paged_variant(tmp_path, capsys):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {"paged": _variant()}, ["paged"])
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    assert "needs variant 'paged_baseline'" in capsys.readouterr().out


def test_check_bench_gates_paged_throughput_floor(tmp_path, capsys):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {
        "paged": _variant(tokens_per_s=80.0),
        "paged_baseline": _variant(tokens_per_s=100.0),
        "paged_shared": _shared_variant()}, [])
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    assert "no longer free" in capsys.readouterr().out


def test_check_bench_gates_shared_bytes_ceiling(tmp_path, capsys):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {
        "paged": _variant(),
        "paged_baseline": _variant(),
        "paged_shared": _shared_variant(kv_bytes_peak=150)}, [])
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    assert "contiguous footprint" in capsys.readouterr().out


def test_check_bench_gates_prefix_must_hit(tmp_path, capsys):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {
        "paged": _variant(),
        "paged_baseline": _variant(),
        "paged_shared": _shared_variant(prefix_hits=0)}, [])
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    assert "zero prefix hits" in capsys.readouterr().out


def test_check_bench_accepts_healthy_paged(tmp_path):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {
        "paged": _variant(tokens_per_s=99.0),
        "paged_baseline": _variant(tokens_per_s=100.0),
        "paged_shared": _shared_variant()},
        ["paged", "paged_baseline", "paged_shared"])
    assert cb.main([str(path)]) == 0
