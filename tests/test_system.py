"""End-to-end behaviour: every assigned architecture (reduced config) runs a
forward pass, a train step, and a prefill+decode cycle on CPU — shapes and
finiteness asserted (deliverable (f) smoke tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.sharding.ctx import default_ctx
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

ARCHS = configs.list_archs()


def _assert_logits_close(a, b, cfg):
    """MoE top-k routing is discrete: bf16 noise can flip a tie and change a
    few tokens' expert mix entirely, so pointwise rtol is brittle on MoE
    archs. Require tight agreement in bulk + bounded outlier fraction."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    diff = np.abs(a - b)
    tol = 0.15 + 0.15 * np.abs(b)
    frac_bad = float(np.mean(diff > tol))
    is_moe = cfg.moe is not None and cfg.moe.n_experts > 0
    allowed = 0.05 if is_moe else 0.002
    assert frac_bad <= allowed, (frac_bad, float(diff.max()))
    assert float(np.median(diff)) < 0.05


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend.kind != "none":
        batch["embeds"] = jax.random.normal(
            k, (b, cfg.frontend.n_embeds, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    hidden, aux = jax.jit(lambda p, b: lm.forward(p, cfg, b))(params, batch)
    n_fr = cfg.frontend.n_embeds if cfg.frontend.kind != "none" else 0
    assert hidden.shape == (2, 32 + n_fr, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
    logits = lm.logits_fn(params, cfg, hidden)
    assert logits.shape[-1] == lm.padded_vocab(cfg)
    # padding logits are masked: argmax always lands on a real token
    assert int(jnp.max(jnp.argmax(logits, -1))) < cfg.vocab_size


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    cfg = configs.get_smoke_config(arch)
    ctx = default_ctx()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=5e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, ctx, opt_cfg))
    batch = _batch(cfg)
    losses = []
    for i in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses   # same batch: must memorize


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == parallel forward logits."""
    cfg = configs.get_smoke_config(arch)
    ctx = default_ctx()
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)
    n_fr = cfg.frontend.n_embeds if cfg.frontend.kind != "none" else 0
    if n_fr:
        pytest.skip("frontend archs prepend embeds; covered in prefill test")
    hidden, _ = lm.forward(params, cfg, {"tokens": tokens}, ctx)
    ref_logits = lm.logits_fn(params, cfg, hidden)

    state = lm.init_decode_state(cfg, b, 32, ctx)
    step = jax.jit(lambda p, st, t: lm.decode_step(p, cfg, st, t, ctx))
    logits_seq = []
    for i in range(s):
        lg, state = step(params, state, tokens[:, i:i + 1])
        logits_seq.append(lg)
    dec = jnp.concatenate(logits_seq, axis=1)
    _assert_logits_close(dec, ref_logits, cfg)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b", "phi3.5-moe-42b-a6.6b"])
def test_prefill_then_decode_consistent(arch):
    cfg = configs.get_smoke_config(arch)
    ctx = default_ctx()
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)
    # prefill path
    state = lm.init_decode_state(cfg, b, 32, ctx)
    lg_pre, state = lm.decode_step(params, cfg, state, tokens, ctx)
    # per-token decode path
    state2 = lm.init_decode_state(cfg, b, 32, ctx)
    for i in range(s):
        lg_tok, state2 = lm.decode_step(params, cfg, state2,
                                        tokens[:, i:i + 1], ctx)
    _assert_logits_close(lg_pre[:, -1], lg_tok[:, 0], cfg)


def test_quantized_kv_decode_close():
    cfg = configs.get_smoke_config("granite-3-8b")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)
    outs = {}
    for qkv in (False, True):
        ctx = dataclasses.replace(default_ctx(), quantized_kv=qkv)
        state = lm.init_decode_state(cfg, b, 32, ctx)
        lg, state = lm.decode_step(params, cfg, state, tokens, ctx)
        lg2, _ = lm.decode_step(params, cfg, state,
                                jnp.argmax(lg[:, -1:], -1), ctx)
        outs[qkv] = np.asarray(lg2, np.float32)
    err = np.abs(outs[True] - outs[False]).max()
    assert err < 0.6, f"int8 KV cache diverges: {err}"


def test_loss_chunking_invariant():
    """CE loss is identical whichever ce_chunk is used."""
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, s=31)
    l1, _ = lm.loss_fn(params, cfg, batch, ce_chunk=512)
    l2, _ = lm.loss_fn(params, cfg, batch, ce_chunk=7)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)


def test_vlm_frontend_changes_output():
    """Patch embeddings must influence text logits (frontend is wired in)."""
    cfg = configs.get_smoke_config("phi-3-vision-4.2b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg, s=16)
    h1, _ = lm.forward(params, cfg, b)
    b2 = dict(b, embeds=b["embeds"] + 1.0)
    h2, _ = lm.forward(params, cfg, b2)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-3
