"""Continuous-batching engine: slot lifecycle, scheduling, and numerics.

The load-bearing guarantees:
  * admission with a full batch queues; eviction on EOS frees the slot;
  * interleaved chunked prefill + batched decode is TOKEN-IDENTICAL to the
    serial single-request path (the acceptance bar for `serve --engine`);
  * an HQP ``QuantizedLinear`` artifact serves through the engine with the
    same tokens as raw ``decode_step`` on that artifact.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving import (Engine, Request, SchedulerConfig, serial_decode)
from repro.serving import state_pool as sp
from repro.sharding.ctx import default_ctx

ARCH = "qwen3-0.6b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]


# ------------------------------------------------------------ slot lifecycle
def test_admission_with_full_batch_queues(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    reqs = [Request(prompt=p, max_new_tokens=4)
            for p in _prompts(cfg, [6, 6, 6, 6])]
    uids = [eng.submit(r) for r in reqs]
    assert eng.n_active == 0 and len(eng.waiting) == 4
    peak = 0
    results = {}
    while eng.has_work:
        for res in eng.step():
            results[res.uid] = res
        peak = max(peak, eng.n_active)
        assert eng.n_active <= 2          # batch never exceeds slot count
    assert peak == 2                       # ...but does fill up
    assert sorted(results) == sorted(uids)
    assert all(len(r.tokens) == 4 for r in results.values())


def test_eviction_on_eos_frees_slot_for_waiting(setup):
    cfg, params = setup
    prompts = _prompts(cfg, [8, 8, 8], seed=1)
    # find what the model actually emits first for prompt 0, use it as EOS
    first_tok = serial_decode(params, cfg, prompts[0], 1, max_seq=64)[0]
    eng = Engine(params, cfg, n_slots=1, max_seq=64)
    eos_req = Request(prompt=prompts[0], max_new_tokens=10, eos_id=first_tok)
    long_req = Request(prompt=prompts[1], max_new_tokens=3)
    u0, u1 = eng.submit(eos_req), eng.submit(long_req)
    results = {}
    admit_order = []
    while eng.has_work:
        busy_before = {s.idx for s in eng.slots if s.stage != "free"}
        for res in eng.step():
            results[res.uid] = res
        for s in eng.slots:
            if s.stage != "free" and s.idx not in busy_before and s.result:
                admit_order.append(s.result.uid)
    assert results[u0].finish_reason == "eos"
    assert results[u0].tokens == [first_tok]      # stopped at EOS, slot freed
    assert results[u1].finish_reason == "length"
    assert len(results[u1].tokens) == 3           # waiting request completed


# ------------------------------------------------------------------ numerics
def test_interleaved_prefill_decode_token_identical(setup):
    """3 overlapping requests, staggered arrivals, chunked prefill — outputs
    must equal serial whole-prompt prefill + per-token decode exactly."""
    cfg, params = setup
    prompts = _prompts(cfg, [13, 7, 18], seed=2)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    eng = Engine(params, cfg, n_slots=3, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=5))
    uids = [eng.submit(r) for r in reqs[:1]]
    results = {}
    # stagger: submit the rest mid-flight so prefill interleaves decode
    for tick in range(1000):
        if not eng.has_work and len(results) == 3:
            break
        if tick == 2:
            uids.append(eng.submit(reqs[1]))
        if tick == 6:
            uids.append(eng.submit(reqs[2]))
        for res in eng.step():
            results[res.uid] = res
    assert eng.stats["decode_ticks"] > 0 and eng.stats["prefill_ticks"] >= 3
    for uid, prompt in zip(uids, prompts):
        ref = serial_decode(params, cfg, prompt, 6, max_seq=64)
        assert results[uid].tokens == ref, (uid, results[uid].tokens, ref)


def test_engine_matches_decode_step_on_artifact(setup):
    """Engine on a QuantizedLinear artifact == raw decode_step greedy loop
    on the same artifact (INT8 weights + INT8 KV cache)."""
    cfg, params = setup
    from repro.compress import compress
    art = compress(params, cfg, log=lambda s: None)   # PTQ-only artifact
    ctx = dataclasses.replace(default_ctx(), quantized_kv=True)
    prompts = _prompts(cfg, [9, 14], seed=3)
    eng = Engine(art.params, cfg, ctx=ctx, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=4))
    res = eng.run([Request(prompt=p, max_new_tokens=5) for p in prompts])
    for uid, prompt in enumerate(prompts):
        ref = serial_decode(art.params, cfg, prompt, 5, ctx=ctx, max_seq=64)
        assert res[uid].tokens == ref


def test_xlstm_engine_matches_serial_token_identical():
    """Fully recurrent config (xLSTM mLSTM/sLSTM blocks, zero attention
    layers): every pool entry keeps its slot axis and routes through the
    ``is_kv_entry == False`` branch of the slot gather/scatter — the
    discriminator path that KV-centric configs never touch. Engine output
    must still equal serial decode token-for-token, with staggered
    arrivals and chunked prefill interleaving decode."""
    cfg = configs.get_smoke_config("xlstm-1.3b")
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    # the whole pool must be recurrent state: no entry may look like KV
    pool = sp.init_pool(cfg, 2, 64, default_ctx(), params=params)
    assert pool["caches"] and all(not sp.is_kv_entry(e)
                                  for e in pool["caches"])
    prompts = _prompts(cfg, [11, 6, 17], seed=5)
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=5))
    res = eng.run([Request(prompt=p, max_new_tokens=6) for p in prompts],
                  arrival_ticks=[0, 2, 4])
    for idx, prompt in enumerate(prompts):
        ref = serial_decode(params, cfg, prompt, 6, max_seq=64)
        assert res[idx].tokens == ref, (idx, res[idx].tokens, ref)


# ------------------------------------------------------------------ pool ops
def test_state_pool_gather_scatter_roundtrip(setup):
    cfg, params = setup
    ctx = default_ctx()
    pool = sp.init_pool(cfg, 3, 32, ctx, params=params)
    assert pool["pos"].shape == (3,)
    single = sp.init_slot_template(cfg, 32, ctx, params=params)
    # run one real prefill into the template, scatter to slot 1, gather back
    toks = np.arange(8, dtype=np.int32)[None]
    _, filled = lm.decode_step(params, cfg, single, jax.numpy.asarray(toks),
                               ctx)
    pool2 = sp.scatter_slot(pool, 1, filled)
    back = sp.gather_slot(pool2, 1)
    assert int(back["pos"]) == 8
    a = jax.tree_util.tree_leaves(back["caches"])
    b = jax.tree_util.tree_leaves(filled["caches"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # other slots untouched
    other = sp.gather_slot(pool2, 0)
    assert int(other["pos"]) == 0


def test_submit_validates_budget(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=list(range(12)), max_new_tokens=8))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=[], max_new_tokens=2))


def test_run_twice_keeps_staggered_arrivals(setup):
    """arrival_ticks are relative to each run's start: a reused engine (the
    bench warmup pattern) must not collapse the second run into a burst."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64)
    reqs = [Request(prompt=p, max_new_tokens=2)
            for p in _prompts(cfg, [6, 6], seed=4)]
    arrivals = [0, 500]        # req 1 arrives long after req 0 finished
    assert len(eng.run(reqs, arrival_ticks=arrivals)) == 2
    ticks_after_warmup = eng.ticks
    assert ticks_after_warmup >= 500
    # second run: if arrivals were compared against absolute engine ticks,
    # both requests would admit instantly at its start
    results = eng.run(reqs, arrival_ticks=arrivals)
    assert len(results) == 2
    assert all(len(r.tokens) == 2 for r in results.values())
    # with a 500-tick gap and 2-token requests, the engine must go idle
    # between them: total ticks advance by >= 500 again
    assert eng.ticks - ticks_after_warmup >= 500


# ------------------------------------------------------------ multi-step
def test_multi_step_decode_token_identical_and_fewer_syncs(setup):
    """decode_steps=8: EOS and length stops land mid-scan (max_new_tokens=6
    is not a multiple of 8), outputs stay token-identical to serial decode,
    and the host syncs far less often than it runs device decode steps."""
    cfg, params = setup
    prompts = _prompts(cfg, [9, 5, 14], seed=7)
    # make request 0 stop via EOS partway through its budget
    eos_tok = serial_decode(params, cfg, prompts[0], 3, max_seq=64)[2]
    eng = Engine(params, cfg, n_slots=3, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=4, decode_steps=8))
    reqs = [Request(prompt=prompts[0], max_new_tokens=6, eos_id=eos_tok),
            Request(prompt=prompts[1], max_new_tokens=6),
            Request(prompt=prompts[2], max_new_tokens=6)]
    results = eng.run(reqs)
    for i, req in enumerate(reqs):
        ref = serial_decode(params, cfg, req.prompt, req.max_new_tokens,
                            max_seq=64, eos_id=req.eos_id)
        assert results[i].tokens == ref, (i, results[i].tokens, ref)
    assert results[0].finish_reason == "eos"
    assert eng.stats["device_steps"] == 8 * eng.stats["decode_ticks"]
    # the whole point: decode tokens arrive in far fewer syncs than steps
    assert eng.stats["host_syncs"] < eng.stats["device_steps"]
    assert eng.stats["decode_slot_steps"] <= eng.stats["device_steps"] * 3


def test_decode_steps_one_matches_multi(setup):
    """decode_steps=1 (the legacy per-token-sync regime) and the default
    multi-step loop must produce identical tokens for identical loads."""
    cfg, params = setup
    prompts = _prompts(cfg, [7, 11], seed=8)
    outs = []
    for ds in (1, 4):
        eng = Engine(params, cfg, n_slots=2, max_seq=64,
                     sched=SchedulerConfig(prefill_chunk=4, decode_steps=ds))
        res = eng.run([Request(prompt=p, max_new_tokens=5) for p in prompts])
        outs.append({i: r.tokens for i, r in res.items()})
    assert outs[0] == outs[1]


# --------------------------------------------------------- window debugging
def test_undersized_prefill_window_caught_by_debug_check(setup, monkeypatch):
    """A host caller that miscomputes the static window silently attends a
    truncated cache and emits wrong tokens — REPRO_DEBUG_WINDOW=1 must turn
    that into an immediate host-side error before the prefill dispatch."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    monkeypatch.setenv("REPRO_DEBUG_WINDOW", "1")
    # sabotage: fixed 8-wide window, too small once prefill passes chunk 1
    monkeypatch.setattr(eng.scheduler, "visible_window",
                        lambda needed, max_seq: 8)
    eng.submit(Request(prompt=list(range(1, 13)), max_new_tokens=2))
    with pytest.raises(AssertionError, match="undersized visible window"):
        while eng.has_work:
            eng.step()


def test_undersized_decode_window_caught_by_debug_check(setup, monkeypatch):
    """Same guard on the decode dispatch: an 8-token prompt prefills fine
    under a pinned 8-wide window, but the first decode step needs
    pos + decode_steps = 12 visible positions."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8, decode_steps=4))
    monkeypatch.setenv("REPRO_DEBUG_WINDOW", "1")
    monkeypatch.setattr(eng.scheduler, "visible_window",
                        lambda needed, max_seq: 8)
    eng.submit(Request(prompt=list(range(1, 9)), max_new_tokens=4))
    with pytest.raises(AssertionError, match="undersized visible window"):
        while eng.has_work:
            eng.step()


def test_debug_window_check_passes_on_correct_windows(setup, monkeypatch):
    """With the real scheduler the armed check must never fire, and outputs
    stay token-identical to serial decode."""
    cfg, params = setup
    monkeypatch.setenv("REPRO_DEBUG_WINDOW", "1")
    prompts = _prompts(cfg, [9, 17], seed=6)
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=5))
    res = eng.run([Request(prompt=p, max_new_tokens=4) for p in prompts])
    for i, p in enumerate(prompts):
        assert res[i].tokens == serial_decode(params, cfg, p, 4, max_seq=64)


def test_summarize_results_empty():
    """A zero-request result set must summarize to zeros, not IndexError."""
    from repro.serving import summarize_results
    s = summarize_results({}, wall_s=1.0)
    assert s["n_requests"] == 0 and s["tokens_per_s"] == 0.0
    assert s["latency_p95_ms"] == 0.0 and s["ttft_p50_ms"] == 0.0


# ----------------------------------------------------------------- launcher
def test_load_artifact_serves_without_calibration(setup, tmp_path,
                                                  monkeypatch):
    """`serve --load-artifact` must never re-run sensitivity/calibration:
    a saved artifact already paid for its Fisher pass."""
    cfg, params = setup
    from repro.compress import compress
    from repro.launch import serve
    from repro.launch.checkpoint import save_artifact
    art = compress(params, cfg, log=lambda s: None)
    save_artifact(str(tmp_path / "art"), art)

    import repro.core.sensitivity as sens

    def _boom(*a, **k):
        raise AssertionError("calibration ran on the --load-artifact path")

    monkeypatch.setattr(sens, "fisher_diag", _boom)
    serve.main(["--smoke", "--load-artifact", str(tmp_path / "art"),
                "--batch", "2", "--prompt-len", "8", "--tokens", "4"])


def test_serve_engine_trace_replay(setup, tmp_path):
    """`serve --engine --trace` replays a JSONL trace and self-verifies
    against serial decode (the CI acceptance path)."""
    import json
    from repro.launch import serve
    trace = tmp_path / "trace.jsonl"
    lines = [{"arrival_s": 0.0, "prompt_len": 9, "max_new_tokens": 4},
             {"arrival_s": 0.01, "prompt_len": 5, "max_new_tokens": 4},
             {"arrival_s": 0.02, "prompt_len": 12, "max_new_tokens": 4}]
    trace.write_text("\n".join(json.dumps(d) for d in lines) + "\n")
    stats = serve.main(["--smoke", "--engine", "--trace", str(trace),
                        "--engine-slots", "2", "--prefill-chunk", "4",
                        "--max-seq", "32", "--verify"])
    assert stats["n_requests"] == 3
    assert stats["out_tokens"] == 12
    assert stats["tokens_per_s"] > 0
