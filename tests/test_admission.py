"""Deadline-feasibility admission (DESIGN.md §14).

Load-bearing guarantees:
  * the controller is pure and clockless — throughput EWMAs fed by
    observed (tokens, wall) pairs, no hidden time source — so every
    verdict here is exact arithmetic, no sleeps;
  * it refuses to judge until warm (``min_observations`` of EACH of
    prefill and decode throughput): a cold predictor admitting everything
    beats a cold predictor guessing;
  * verdicts price the FULL backlog ahead of the candidate plus the
    candidate itself, with the safety margin, and an infeasible verdict
    carries an honest computed Retry-After (predicted minus deadline,
    clamped to [floor, cap]) — never a made-up constant;
  * ``Service.submit`` sheds infeasible deadlines AT SUBMIT (before the
    request burns a queue position), counts them in both ``shed`` and
    ``shed_infeasible``, and leaves the why in ``last_shed`` for the
    transport's status code and Retry-After header;
  * the static ``n_slots + queue_depth`` cap stays a hard bound on top —
    feasibility never admits past saturation;
  * ``scripts/check_bench.py`` gates the chaos + feasibility variants by
    NAME with measured-vs-threshold messages.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving import (AdmissionConfig, AdmissionController, Engine,
                           Request, SchedulerConfig, Service, ServiceConfig)
from test_paged_kv import _bench_doc, _load_check_bench, _variant

ARCH = "qwen3-0.6b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, **cfg_kw):
    """A controller warmed to exact, known rates: every observation is
    (rate tokens / 1 s), so the EWMA converges to the rate itself and
    work_s becomes closed-form checkable."""
    ctrl = AdmissionController(AdmissionConfig(**cfg_kw))
    for _ in range(ctrl.cfg.min_observations):
        ctrl.observe(prefill_rate, decode_rate, 1.0)
    return ctrl


def _fake_clock():
    now = [0.0]
    return now, (lambda: now[0])


# ------------------------------------------------------------- pure controller
def test_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(ewma_alpha=1.5)
    with pytest.raises(ValueError):
        AdmissionConfig(safety=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(min_observations=0)
    with pytest.raises(ValueError):
        AdmissionConfig(retry_floor_s=2.0, retry_cap_s=1.0)


def test_cold_controller_never_judges():
    ctrl = AdmissionController()
    assert not ctrl.warm
    # one observation short of warm on the decode side
    for _ in range(ctrl.cfg.min_observations):
        ctrl.observe(100, 0, 1.0)            # prefill-only steps
    for _ in range(ctrl.cfg.min_observations - 1):
        ctrl.observe(0, 50, 1.0)
    assert not ctrl.warm
    ctrl.observe(0, 50, 1.0)
    assert ctrl.warm


def test_observe_ignores_degenerate_samples():
    ctrl = AdmissionController()
    ctrl.observe(100, 100, 0.0)              # no wall time elapsed
    ctrl.observe(100, 100, -1.0)
    ctrl.observe(0, 0, 1.0)                  # a tick that moved no tokens
    assert not ctrl.warm


def test_ewma_tracks_rate_change():
    ctrl = _warm_ctrl(decode_rate=100.0)
    fast = ctrl.work_s(0, 100)               # ~1s of decode, x safety
    for _ in range(40):
        ctrl.observe(0, 50, 1.0)             # throughput halves
    assert ctrl.work_s(0, 100) > 1.8 * fast  # prediction roughly doubles


def test_work_s_closed_form():
    ctrl = _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, safety=1.5)
    # 500 prefill tokens at 1000 tok/s + 20 decode at 100 tok/s = 0.7 s
    assert ctrl.work_s(500, 20) == pytest.approx(1.5 * 0.7, rel=1e-6)


def test_feasible_verdict_and_honest_retry():
    ctrl = _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, safety=1.0,
                      retry_floor_s=0.05, retry_cap_s=30.0)
    # candidate alone: 100/1000 + 10/100 = 0.2 s predicted
    v = ctrl.feasible(100, 10, (0, 0), deadline_s=1.0)
    assert v.feasible and v.retry_after_s == 0.0
    assert v.predicted_s == pytest.approx(0.2, rel=1e-6)
    # same candidate behind 200 backlog decode tokens (2 s at 100 tok/s):
    # 0.1 prefill + 2.1 decode = 2.2 s predicted
    v = ctrl.feasible(100, 10, (0, 200), deadline_s=1.0)
    assert not v.feasible
    assert v.predicted_s == pytest.approx(2.2, rel=1e-6)
    # honest retry: predicted - deadline, not a constant
    assert v.retry_after_s == pytest.approx(1.2, rel=1e-6)


def test_retry_clamps_to_floor_and_cap():
    ctrl = _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, safety=1.0,
                      retry_floor_s=0.5, retry_cap_s=2.0)
    barely = ctrl.feasible(100, 10, (0, 0), deadline_s=0.19)
    assert not barely.feasible and barely.retry_after_s == 0.5   # floor
    hopeless = ctrl.feasible(100_000, 10_000, (0, 0), deadline_s=0.1)
    assert not hopeless.feasible and hopeless.retry_after_s == 2.0  # cap


# --------------------------------------------------------- service integration
def test_submit_sheds_infeasible_at_submit(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    ctrl = _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, safety=1.0)
    now, clock = _fake_clock()
    svc = Service(eng, ServiceConfig(queue_depth=4), clock=clock,
                  admission=ctrl)
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, 10).tolist()

    # 10 decode tokens need ~0.1 s — a 1 ms deadline is impossible, and
    # the shed happens NOW, with nothing ever entering the engine
    t = svc.submit(Request(prompt=prompt, max_new_tokens=10),
                   deadline_s=0.001)
    assert t is None
    assert svc.stats["shed"] == 1 and svc.stats["shed_infeasible"] == 1
    assert svc.stats["submitted"] == 0 and not eng.has_work
    assert svc.last_shed["reason"] == "infeasible"
    assert svc.last_shed["retry_after_s"] > 0
    assert svc.last_shed["predicted_s"] > 0.001

    # a generous deadline on the same request sails through and completes
    t = svc.submit(Request(prompt=prompt, max_new_tokens=10),
                   deadline_s=60.0)
    assert t is not None
    while svc.has_work:
        svc.step()
    assert t.finish_reason == "length"
    assert svc.stats["expired"] == 0 and svc.stats["completed"] == 1


def test_feasibility_prices_backlog_of_admitted_work(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    ctrl = _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, safety=1.0)
    now, clock = _fake_clock()
    svc = Service(eng, ServiceConfig(queue_depth=4), clock=clock,
                  admission=ctrl)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 10).tolist()
    # alone, this deadline is fine (~0.31 s predicted vs 1 s)...
    assert ctrl.feasible(10, 30, (0, 0), 1.0).feasible
    a = svc.submit(Request(prompt=prompt, max_new_tokens=30),
                   deadline_s=10.0)
    b = svc.submit(Request(prompt=prompt, max_new_tokens=30),
                   deadline_s=10.0)
    assert a is not None and b is not None
    # ...but behind two 30-token requests the backlog prices at
    # (20+10)/1000 + (60+30)/100 = 0.93 s — a 0.8 s ask is infeasible
    t = svc.submit(Request(prompt=prompt, max_new_tokens=30),
                   deadline_s=0.8)
    assert t is None and svc.last_shed["reason"] == "infeasible"
    # deadline-free requests are NEVER feasibility-checked
    t = svc.submit(Request(prompt=prompt, max_new_tokens=30))
    assert t is not None
    while svc.has_work:
        svc.step()
    assert svc.stats["expired"] == 0 and svc.stats["completed"] == 3


def test_static_cap_still_hard_even_when_feasible(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    # absurdly fast rates: everything looks feasible to the predictor
    ctrl = _warm_ctrl(prefill_rate=1e9, decode_rate=1e9)
    svc = Service(eng, ServiceConfig(queue_depth=1), admission=ctrl)
    rng = np.random.RandomState(2)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, 6).tolist(),
                    max_new_tokens=2) for _ in range(3)]
    assert svc.submit(reqs[0], deadline_s=60.0) is not None
    assert svc.submit(reqs[1], deadline_s=60.0) is not None
    assert svc.submit(reqs[2], deadline_s=60.0) is None   # capacity == 2
    assert svc.last_shed["reason"] == "saturated"
    assert svc.stats["shed"] == 1 and svc.stats["shed_infeasible"] == 0
    svc.drain()


def test_saturation_retry_after_uses_backlog_when_warm(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    ctrl = _warm_ctrl(prefill_rate=1000.0, decode_rate=100.0, safety=1.0,
                      retry_floor_s=0.01, retry_cap_s=30.0)
    svc = Service(eng, ServiceConfig(queue_depth=0, retry_after_s=0.25),
                  admission=ctrl)
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, 10).tolist()
    assert svc.submit(Request(prompt=prompt, max_new_tokens=30)) is not None
    assert svc.submit(Request(prompt=prompt, max_new_tokens=30)) is None
    # one live request owing 10 prefill + 30 decode tokens: ~0.31 s — the
    # advertised Retry-After is that computed drain time, not the static
    # 0.25 s configured fallback
    assert svc.last_shed["reason"] == "saturated"
    assert svc.last_shed["retry_after_s"] == pytest.approx(0.31, rel=1e-6)
    svc.drain()


# ----------------------------------------------------------- check_bench gates
def _chaos_variant(**kw):
    v = _variant(faults=4, leaked_pages=0, survivors=4,
                 survivors_identical=1, pump_survived=1, p95_ratio=0.9,
                 fault_free_p95_ms=40.0)
    v.update(kw)
    return v


def _adm_variant(**kw):
    v = _variant(shed_infeasible=4, expired=0, completed=4,
                 retry_after_s_sample=0.05)
    v.update(kw)
    return v


def test_check_bench_names_missing_chaos_variant(tmp_path, capsys):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {"chaos": _chaos_variant()}, ["chaos"])
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    assert "needs variant 'admission_feasible'" in capsys.readouterr().out


def test_check_bench_gates_chaos_invariants(tmp_path, capsys):
    cb = _load_check_bench()
    for bad, needle in [
        (dict(faults=0), "injectors never fired"),
        (dict(leaked_pages=3), "leaked_pages = 3"),
        (dict(pump_survived=0), "killed the serving loop"),
        (dict(survivors_identical=0), "perturbed a surviving stream"),
        (dict(p95_ratio=5.0), "stalling the batch"),
    ]:
        path = _bench_doc(tmp_path, {
            "chaos": _chaos_variant(**bad),
            "admission_feasible": _adm_variant()}, [])
        with pytest.raises(SystemExit):
            cb.main([str(path)])
        out = capsys.readouterr().out
        assert needle in out, f"{bad} -> {out}"


def test_check_bench_gates_admission_invariants(tmp_path, capsys):
    cb = _load_check_bench()
    for bad, needle in [
        (dict(shed_infeasible=0), "impossible deadlines were admitted"),
        (dict(expired=2), "blew its deadline"),
        (dict(completed=0), "starved"),
        (dict(retry_after_s_sample=0.0), "honest computed Retry-After"),
    ]:
        path = _bench_doc(tmp_path, {
            "chaos": _chaos_variant(),
            "admission_feasible": _adm_variant(**bad)}, [])
        with pytest.raises(SystemExit):
            cb.main([str(path)])
        out = capsys.readouterr().out
        assert needle in out, f"{bad} -> {out}"


def test_check_bench_accepts_healthy_chaos(tmp_path):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {
        "chaos": _chaos_variant(),
        "admission_feasible": _adm_variant()},
        ["chaos", "admission_feasible"])
    assert cb.main([str(path)]) == 0
