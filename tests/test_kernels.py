"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.quantize import quantize_rowwise_pallas


# ------------------------------------------------------------------ quantize
@pytest.mark.parametrize("m,k", [(8, 64), (256, 128), (33, 100), (1, 256)])
def test_quantize_rowwise_matches_ref(m, k):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32) * 3
    q_p, s_p = quantize_rowwise_pallas(x, interpret=True)
    q_r, s_r = ref.quantize_ref(x, axis=-1)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))


@given(m=st.integers(1, 64), k=st.integers(1, 128), scale=st.floats(0.1, 50))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(m, k, scale):
    """|x - q*s| <= s/2 elementwise (symmetric rounding property)."""
    x = np.asarray(jax.random.normal(
        jax.random.PRNGKey(m * 131 + k), (m, k), jnp.float32)) * scale
    q, s = ref.quantize_ref(jnp.asarray(x), axis=-1)
    deq = np.asarray(q, np.float32) * np.asarray(s)[:, None]
    bound = np.asarray(s)[:, None] * 0.5 + 1e-6
    assert np.all(np.abs(x - deq) <= bound)


# ------------------------------------------------------------------ matmul
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 128, 64, 32, 32, 64),
    (128, 256, 128, 128, 128, 128),
    (100, 96, 50, 32, 32, 32),       # non-aligned, exercises padding
    (8, 512, 256, 256, 256, 512),
    (1, 96, 48, 32, 32, 32),         # single row, all dims padded
    (37, 130, 65, 32, 64, 64),       # prime-ish: padding on every axis
    (130, 100, 257, 128, 128, 128),  # M, K, N all exceed one block + remnant
])
def test_int8_matmul_pallas_vs_ref(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32) * 0.1
    w_q, w_s = ref.quantize_ref(w, axis=0)           # per-out-channel
    x_q, x_s = ref.quantize_ref(x, axis=-1)
    out_p = int8_matmul_pallas(x_q, w_q, x_s, w_s, bm=bm, bn=bn, bk=bk,
                               interpret=True)
    out_r = ref.int8_matmul_ref(x_q, w_q, w_s, x_s)
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_int8_matmul_close_to_fp():
    """W8A8 result approximates the fp32 matmul within quantization error."""
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (64, 256), jnp.float32)
    w = jax.random.normal(k2, (256, 128), jnp.float32) * 0.05
    w_q, w_s = ref.quantize_ref(w, axis=0)
    out = np.asarray(ref.int8_matmul_ref(x, w_q, w_s), np.float32)
    expected = np.asarray(x @ w)
    rel = np.abs(out - expected) / (np.abs(expected) + 1e-2)
    assert np.median(rel) < 0.05


# ------------------------------------------------------------------ flash
@pytest.mark.parametrize("bh,s,hd,bq,bk", [
    (4, 128, 64, 64, 64),
    (2, 256, 32, 128, 64),
    (1, 64, 128, 64, 64),
])
def test_flash_attention_pallas_vs_ref(bh, s, hd, bq, bk):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (bh, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, hd), jnp.float32)
    out = flash_attention_pallas(q, k, v, bq=bq, bk=bk, interpret=True)
    # oracle expects (B, S, H, hd)
    o_ref = ref.flash_attention_ref(q[:, :, None, :], k[:, :, None, :],
                                    v[:, :, None, :])[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_jnp_flash_matches_naive():
    """The model's chunked online-softmax path == naive attention."""
    from repro.models.attention import flash_attention
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    b, s, hq, hkv, hd = 2, 128, 8, 4, 32
    q = jax.random.normal(ks[0], (b, s, hq, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.bfloat16)
    out = flash_attention(q, k, v, chunk_kv=32)
    # naive GQA reference
    kr = jnp.repeat(k, hq // hkv, axis=2)
    vr = jnp.repeat(v, hq // hkv, axis=2)
    o_ref = ref.flash_attention_ref(q, kr, vr)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@given(s=st.sampled_from([64, 128]), hd=st.sampled_from([32, 64]),
       seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(s, hd, seed):
    """Rows of the attention output are convex combinations of V rows:
    output must lie within [min(V), max(V)] per feature."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (1, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (1, s, hd), jnp.float32)
    out = np.asarray(flash_attention_pallas(q, k, v, bq=s, bk=64,
                                            interpret=True), np.float32)
    vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
    assert out.min() >= vmin - 1e-3 and out.max() <= vmax + 1e-3


@pytest.mark.parametrize("skv,chunk_kv", [(97, 32), (13, 1024), (33, 32),
                                          (127, 64)])
def test_jnp_flash_ragged_lengths(skv, chunk_kv):
    """Regression: ``flash_attention`` used to hard-crash
    (``assert skv % chunk_kv == 0``) on any sequence length that wasn't a
    chunk multiple. Ragged/prime lengths must now pad K/V to a block
    multiple and mask the tail by position — matching the unchunked oracle
    exactly in semantics, both causal and not."""
    from repro.models.attention import flash_attention
    key = jax.random.PRNGKey(skv)
    ks = jax.random.split(key, 3)
    b, hq, hkv, hd = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (b, skv, hq, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd), jnp.bfloat16)
    kr, vr = (jnp.repeat(t, hq // hkv, axis=2) for t in (k, v))
    for causal in (True, False):
        out = flash_attention(q, k, v, causal=causal, chunk_kv=chunk_kv)
        o_ref = ref.flash_attention_ref(q, kr, vr, causal=causal)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_causal_convention_absolute_positions_cross_shape():
    """One Sq<Skv causal convention, everywhere: queries sit at absolute
    positions ``q_offset + i`` (FIRST Sq by default) in
    ``flash_attention_ref``, the model's chunked flash path, and the
    cache-attention oracle (``start == q_offset``). The old oracle pinned
    queries to the LAST Sq positions (``tril k=skv-sq``) while the model
    assumed the first — a silent drift the prefill kernel would otherwise
    have validated against."""
    from repro.models.attention import flash_attention
    key = jax.random.PRNGKey(21)
    ks = jax.random.split(key, 3)
    b, h, hd, sq, skv = 2, 4, 32, 5, 24
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, skv, h, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, skv, h, hd), jnp.bfloat16)
    for off in (0, 7, skv - sq):
        o_flash = ref.flash_attention_ref(q, k, v, q_offset=off)
        o_model = flash_attention(q, k, v, chunk_kv=8, q_offset=off)
        o_cached = ref.cached_attention_ref(
            q, k, v, start=jnp.full((b,), off, jnp.int32))
        np.testing.assert_allclose(np.asarray(o_flash, np.float32),
                                   np.asarray(o_cached, np.float32),
                                   rtol=3e-2, atol=3e-2)
        np.testing.assert_allclose(np.asarray(o_model, np.float32),
                                   np.asarray(o_cached, np.float32),
                                   rtol=3e-2, atol=3e-2)
    # sharp semantic pin: with q_offset=0, query 0 sees ONLY kv position 0,
    # so its output must be exactly v[:, 0] (a softmax over one score)
    o0 = ref.flash_attention_ref(q, k, v, q_offset=0)
    np.testing.assert_allclose(np.asarray(o0[:, 0], np.float32),
                               np.asarray(v[:, 0], np.float32),
                               rtol=1e-6, atol=1e-6)


def test_int8_decode_attention_ref_close_to_fp():
    """decode_attention_ref on a quantized KV cache (int8 + per-(pos,head)
    scales, dequant fused on the score/probability side) must approximate
    full-precision attention within quantization error."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    b, s, h, hd = 2, 64, 4, 32
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    from repro.models.attention import _quant_kv
    kq, ksc = _quant_kv(kc)
    vq, vsc = _quant_kv(vc)
    out = ref.decode_attention_ref(q, kq, vq, ksc, vsc,
                                   jnp.full((b,), s - 1, jnp.int32))
    # fp reference via naive attention on last position
    scores = np.einsum("bhd,bshd->bhs", np.asarray(q), np.asarray(kc)) / np.sqrt(hd)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o_ref = np.einsum("bhs,bshd->bhd", p, np.asarray(vc))
    np.testing.assert_allclose(np.asarray(out, np.float32), o_ref,
                               rtol=5e-2, atol=5e-2)
