"""Roofline analyzer: loop-aware flop/byte/collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = _compile(scanned, x, ws)
    res = hlo_cost.analyze(c.as_text())
    expected = 8 * 2 * 128 * 256 * 256
    assert abs(res.flops - expected) / expected < 0.01
    # XLA's own analysis undercounts by the trip count — this is WHY the
    # custom analyzer exists; pin the discrepancy so a fixed XLA flips here.
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert float(ca["flops"]) < expected / 2


def test_plain_matmul_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    res = hlo_cost.analyze(_compile(f, a, b).as_text())
    assert res.flops == 2 * 64 * 128 * 32


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    res = hlo_cost.analyze(_compile(f, a, b).as_text())
    assert res.flops == 2 * 4 * 16 * 32 * 8


def test_int8_dot_flagged():
    def f(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    a = jax.ShapeDtypeStruct((32, 64), jnp.int8)
    b = jax.ShapeDtypeStruct((64, 16), jnp.int8)
    res = hlo_cost.analyze(_compile(f, a, b).as_text())
    assert res.int8_dot_flops == res.flops > 0


def test_bytes_scale_with_scan_length():
    def make(n):
        def body(x, w):
            return jnp.tanh(x @ w), None

        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)
        return hlo_cost.analyze(_compile(scanned, x, ws).as_text())
    b4, b16 = make(4).bytes, make(16).bytes
    assert 3.0 < b16 / b4 < 5.0          # ~4x with fixed overheads


def test_collective_detection_via_shard_map():
    if jax.device_count() < 1:
        pytest.skip("no devices")
    # single-device mesh: psum still lowers to an all-reduce op in HLO
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("x",))

    def f(a):
        return shard_map(lambda t: jax.lax.psum(t, "x"), mesh=mesh,
                         in_specs=P("x"), out_specs=P())(a)
    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    # lowered stablehlo won't parse; compile instead
    c = jax.jit(f).lower(a).compile()
    res = hlo_cost.analyze(c.as_text())
    # on 1 device XLA may elide the all-reduce; accept either but the parser
    # must not crash and bytes must be positive
    assert res.bytes > 0
