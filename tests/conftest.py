import os
import sys

# Tests run on the single host CPU device (the dry-run, and only the dry-run,
# forces 512 devices — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# the tests dir itself, for the optional-dependency stubs (_hypothesis_stub)
sys.path.insert(0, os.path.dirname(__file__))
