"""Stand-ins for ``hypothesis`` so property tests skip cleanly (rather than
failing collection) on a bare container without the package installed.

``given`` swallows the test body and returns a no-arg skipper — signatures
are deliberately NOT preserved so pytest doesn't go hunting for fixtures
named after hypothesis strategy kwargs.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _AnyStrategy:
    """st.<anything>(...) -> None; only ever fed to the stub ``given``."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AnyStrategy()
