"""AdamW: int8-blockwise state tracks fp32 dynamics; grad clipping works."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"a": {"w": jax.random.normal(k1, (64, 32), jnp.float32)},
            "b": jax.random.normal(k2, (100,), jnp.float32)}


def test_int8_state_tracks_f32():
    key = jax.random.PRNGKey(0)
    params = _toy_params(key)
    cfg32 = AdamWConfig(lr=1e-2, state_dtype="f32")
    cfg8 = AdamWConfig(lr=1e-2, state_dtype="int8")
    p32, s32 = params, adamw_init(params, cfg32)
    p8, s8 = params, adamw_init(params, cfg8)
    for i in range(20):
        g = jax.tree.map(
            lambda p: jnp.sin(p * (i + 1)) * 0.1, params)
        p32, s32 = jax.jit(lambda p, g, s: adamw_update(p, g, s, cfg32))(p32, g, s32)
        p8, s8 = jax.jit(lambda p, g, s: adamw_update(p, g, s, cfg8))(p8, g, s8)
    d32 = np.asarray(p32["a"]["w"] - params["a"]["w"])
    d8 = np.asarray(p8["a"]["w"] - params["a"]["w"])
    rel = np.linalg.norm(d8 - d32) / (np.linalg.norm(d32) + 1e-9)
    assert rel < 0.08, rel   # sqrt-mapped v: ~5%; linear v was ~14%


def test_grad_clip_caps_update():
    params = {"w": jnp.zeros((10,), jnp.float32)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    state = adamw_init(params, cfg)
    g = {"w": jnp.full((10,), 1e6, jnp.float32)}
    p2, _ = adamw_update(params, g, state, cfg)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_loss_descends_on_quadratic():
    cfg = AdamWConfig(lr=8e-2, state_dtype="int8", weight_decay=0.0)
    params = {"w": jnp.ones((16,), jnp.float32) * 3}
    state = adamw_init(params, cfg)
    for _ in range(120):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw_update(params, g, state, cfg)
    assert float(jnp.sum(params["w"] ** 2)) < 1.0
