"""Telemetry plane: histogram math, exposition, spans, phase attribution.

The load-bearing guarantees (DESIGN.md §16):
  * histogram buckets follow Prometheus ``le`` semantics — inclusive
    upper edges, an implicit +Inf overflow — and merge bucket-wise only
    when the edges match;
  * ``MetricsRegistry.render`` emits well-formed text exposition v0.0.4
    (golden-tested), ``parse_exposition`` round-trips it exactly, and
    label escaping survives backslash/quote/newline;
  * adopted stats dicts stay the writable source of truth: the registry
    reads live values at render time and REJECTS undeclared keys;
  * the span recorder gives every submitted uid exactly one terminal,
    and ``queued + active`` tiles the ``request`` envelope — including
    under injected engine faults and admit/cancel/expiry storms;
  * ``GET /metrics`` on the front door serves the full declared metric
    set mid-conversation, and ``ServiceConfig(telemetry=False)`` turns
    the whole plane off;
  * ``Engine.last_step`` is the single measurement source: the service
    feeds the phase histograms and the admission EWMAs from it, through
    one injected clock shared by engine, service, and recorder.
"""
import asyncio
import json

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.models import lm
from repro.serving import (Engine, HttpFrontDoor, Request, SchedulerConfig,
                           Service, ServiceConfig, faults)
from repro.telemetry import (Histogram, MetricsRegistry, SpanRecorder,
                             escape_label, parse_exposition, schema)

ARCH = "qwen3-0.6b"


# ------------------------------------------------------- histogram math
def test_histogram_le_edges_are_inclusive():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v, bucket in ((0.5, 0), (1.0, 0), (1.5, 1), (2.0, 1),
                      (2.0000001, 2), (4.0, 2), (4.5, 3), (100.0, 3)):
        before = list(h.counts)
        h.observe(v)
        assert h.counts[bucket] == before[bucket] + 1, \
            f"{v} should land in bucket {bucket} (le semantics)"
    assert h.count == 8 and h.counts[-1] == 2     # +Inf overflow holds 2
    assert h.sum == pytest.approx(0.5 + 1.0 + 1.5 + 2.0 + 2.0000001
                                  + 4.0 + 4.5 + 100.0)


def test_histogram_merge_and_dict_roundtrip():
    a = Histogram("h", buckets=(1.0, 2.0))
    b = Histogram("h", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 3.0):
        a.observe(v)
        b.observe(v)
    a.merge(b)
    assert a.counts == [2, 2, 2] and a.count == 6
    assert a.sum == pytest.approx(10.0)
    c = Histogram.from_dict(a.to_dict())
    assert (c.counts, c.count, c.sum) == (a.counts, a.count, a.sum)
    assert c.edges == a.edges
    with pytest.raises(ValueError):
        a.merge(Histogram("h", buckets=(1.0, 3.0)))
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))        # not increasing
    with pytest.raises(ValueError):
        Histogram.from_dict({"le": [1.0, 2.0], "counts": [1]})


def test_histogram_quantile_reports_bucket_upper_edge():
    h = Histogram("h", buckets=tuple(float(i) for i in range(1, 11)))
    for v in range(1, 11):                        # one per bucket
        h.observe(v - 0.5)
    assert h.quantile(0.0) == 1.0
    assert h.quantile(0.5) == 5.0
    assert h.quantile(1.0) == 10.0
    assert Histogram("h", buckets=(1.0,)).quantile(0.5) == 0.0
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_log_buckets_shape():
    edges = schema.log_buckets(1e-3, 1.0, per_decade=2)
    assert len(edges) == 7                        # 3 decades x 2 + 1
    assert edges[0] == pytest.approx(1e-3) and edges[-1] == pytest.approx(1.0)
    ratios = [b / a for a, b in zip(edges, edges[1:])]
    assert all(r == pytest.approx(ratios[0]) for r in ratios)
    with pytest.raises(ValueError):
        schema.log_buckets(1.0, 0.1)


# ----------------------------------------------------------- exposition
def test_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("t_total", "total things").inc(2)
    reg.gauge("t_jobs", "live jobs").set(3)
    h = reg.histogram("t_hist", "timings", buckets=(0.1, 1.0), phase="x")
    for v in (0.0625, 0.5, 4.0):
        h.observe(v)
    assert reg.render() == (
        "# HELP t_hist timings\n"
        "# TYPE t_hist histogram\n"
        't_hist_bucket{le="0.1",phase="x"} 1\n'
        't_hist_bucket{le="1",phase="x"} 2\n'
        't_hist_bucket{le="+Inf",phase="x"} 3\n'
        't_hist_sum{phase="x"} 4.5625\n'
        't_hist_count{phase="x"} 3\n'
        "# HELP t_jobs live jobs\n"
        "# TYPE t_jobs gauge\n"
        "t_jobs 3\n"
        "# HELP t_total total things\n"
        "# TYPE t_total counter\n"
        "t_total 2\n")


def test_exposition_parse_roundtrip_and_strictness():
    reg = MetricsRegistry()
    reg.counter("c_one", "a counter").inc(7)
    h = reg.histogram("h_one", "a histogram", buckets=(1.0, 2.0))
    h.observe(1.5)
    parsed = parse_exposition(reg.render())
    assert parsed["types"] == {"c_one": "counter", "h_one": "histogram"}
    s = parsed["samples"]
    assert s[("c_one", ())] == 7
    assert s[("h_one_bucket", (("le", "1"),))] == 0
    assert s[("h_one_bucket", (("le", "2"),))] == 1      # cumulative
    assert s[("h_one_bucket", (("le", "+Inf"),))] == 1
    assert s[("h_one_sum", ())] == 1.5
    assert s[("h_one_count", ())] == 1
    with pytest.raises(ValueError):
        parse_exposition("this is not a sample line at all!\n")
    with pytest.raises(ValueError):
        parse_exposition('m{le="1" garbage} 3\n')


def test_label_escaping_survives_roundtrip():
    nasty = 'back\\slash "quoted"\nnewline'
    assert escape_label(nasty) == \
        'back\\\\slash \\"quoted\\"\\nnewline'
    reg = MetricsRegistry()
    reg.gauge("g_esc", "escaped", tag=nasty).set(1)
    parsed = parse_exposition(reg.render())
    assert parsed["samples"] == {("g_esc", (("tag", nasty),)): 1.0}


def test_register_stats_rejects_undeclared_and_reads_live():
    reg = MetricsRegistry()
    stats = {"submitted": 0}
    reg.register_stats(schema.SERVICE_PREFIX, stats, schema.SERVICE_STATS)
    stats["submitted"] += 41                      # live dict stays writable
    stats["submitted"] += 1
    parsed = parse_exposition(reg.render())
    assert parsed["samples"][(schema.SERVICE_PREFIX + "submitted", ())] == 42
    with pytest.raises(ValueError, match="not_declared"):
        reg.register_stats(schema.SERVICE_PREFIX, {"not_declared": 0},
                           schema.SERVICE_STATS)
    with pytest.raises(ValueError, match="duplicate"):
        reg.gauge("g_dup", "x")
        reg.gauge("g_dup", "x")


# -------------------------------------------------------- span recorder
def _lifecycle_ok(rec: SpanRecorder, uids):
    """Exactly one terminal per uid; queued+active tile request exactly
    (same injected timestamps on both sides, so equality, not 5%)."""
    assert rec.open_uids() == []
    assert sorted(rec.terminals) == sorted(uids)
    by_uid = {}
    for r in rec.records:
        if r.get("uid") is not None:
            by_uid.setdefault(r["uid"], []).append(r)
    for uid in uids:
        recs = by_uid[uid]
        fins = [r for r in recs if r["type"] == "instant"
                and r["name"] == "finish"]
        assert len(fins) == 1 and "duplicate" not in fins[0]["args"], \
            f"uid {uid}: {fins}"
        assert fins[0]["args"]["reason"] in schema.TERMINAL_REASONS
        req = [r for r in recs if r["type"] == "span"
               and r["name"] == "request"]
        assert len(req) == 1
        parts = [r for r in recs if r["type"] == "span"
                 and r["name"] in ("queued", "active")]
        part_dur = sum(r["t1"] - r["t0"] for r in parts)
        req_dur = req[0]["t1"] - req[0]["t0"]
        assert part_dur == pytest.approx(req_dur), f"uid {uid} not tiled"
        parts.sort(key=lambda r: r["t0"])
        for a, b in zip(parts, parts[1:]):
            assert b["t0"] >= a["t1"], f"uid {uid}: overlapping spans"


def test_span_recorder_lifecycle_unit():
    rec = SpanRecorder()
    rec.submit(0, 1.0, prompt_len=8)
    rec.submit(1, 1.5, prompt_len=4)
    rec.admit(0, 2.0, slot=0)
    rec.span("prefill", 0, 2.0, 2.5, lo=0, hi=8, tokens=1)
    rec.first_token(0, 2.5)
    rec.span("decode", 0, 2.5, 3.0, tokens=3, k_steps=4)
    assert rec.open_uids() == [0, 1]
    rec.finish(0, 3.0, "length", n_tokens=4, pages_held=2)
    rec.finish(1, 3.5, "cancelled")               # evicted while queued
    rec.shed(4.0, "saturated")
    _lifecycle_ok(rec, [0, 1])
    assert rec.terminals == {0: "length", 1: "cancelled"}
    assert rec.sheds == 1
    fin0 = [r for r in rec.records if r["type"] == "instant"
            and r["name"] == "finish" and r["uid"] == 0][0]
    assert fin0["args"]["span_tokens"] == 4       # prefill tail + decode
    assert fin0["args"]["pages_held"] == 2
    # never-admitted uid 1: queued alone covers the envelope
    q1 = [r for r in rec.records if r["name"] == "queued"
          and r["uid"] == 1][0]
    assert (q1["t0"], q1["t1"]) == (1.5, 3.5)

    # a double-finish is recorded as an anomaly, never a second terminal
    rec.finish(0, 9.0, "error")
    assert rec.terminals[0] == "length"
    dupes = [r for r in rec.records if r["args"].get("duplicate")]
    assert len(dupes) == 1 and dupes[0]["uid"] == 0


def test_chrome_trace_export_shape():
    rec = SpanRecorder()
    rec.submit(3, 1.0, prompt_len=8)
    rec.admit(3, 2.0, slot=0)
    rec.span("step", None, 1.0, 1.1, total=0.1)
    rec.finish(3, 3.0, "length", n_tokens=0)
    trace = rec.to_chrome_trace()
    evs = trace["traceEvents"]
    names = {e["tid"]: e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert names == {0: "engine", 4: "req 3"}     # tid = uid + 1
    xs = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in xs)
    step = [e for e in xs if e["name"] == "step"][0]
    assert step["tid"] == 0
    assert step["ts"] == pytest.approx(1.0e6)     # microseconds
    req = [e for e in xs if e["name"] == "request"][0]
    assert req["tid"] == 4 and req["args"]["uid"] == 3
    # jsonl round-trips through plain json
    lines = [json.loads(x) for x in rec.to_jsonl().splitlines()]
    assert lines == rec.records


# ------------------------------------------------------- live engine
@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8),
                 page_size=8, prefix_cache=False)
    return cfg, eng


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]


def _ticking_clock(dt=1e-4):
    now = [0.0]

    def clk():
        now[0] += dt
        return now[0]
    return now, clk


def test_live_stats_keys_are_all_declared(setup):
    """Schema completeness against the LIVE objects: every key the engine
    and service actually carry is declared (the lint rule catches writes;
    this catches declared-but-renamed drift)."""
    cfg, eng = setup
    svc = Service(eng, ServiceConfig(queue_depth=2))
    assert set(eng.stats) <= set(schema.ENGINE_STATS)
    assert set(svc.stats) <= set(schema.SERVICE_STATS)
    assert svc.registry is not None
    assert eng.clock is svc.clock                 # one clock, re-pointed
    # every declared family renders before any traffic
    parsed = parse_exposition(svc.render_metrics())
    assert set(schema.metric_names()) <= set(parsed["types"])


def test_telemetry_off_is_off(setup):
    cfg, eng = setup
    svc = Service(eng, ServiceConfig(queue_depth=2, telemetry=False))
    assert svc.registry is None
    assert svc.render_metrics().startswith("# telemetry disabled")
    t = svc.submit(Request(prompt=_prompts(cfg, [6])[0], max_new_tokens=2))
    while svc.has_work:
        svc.step()
    assert t.finish_reason == "length"            # serving path unaffected


def test_last_step_feeds_phase_hists_and_latency(setup):
    cfg, eng = setup
    now, clk = _ticking_clock()
    svc = Service(eng, ServiceConfig(queue_depth=4), clock=clk)
    t = svc.submit(Request(prompt=_prompts(cfg, [10], seed=2)[0],
                           max_new_tokens=3))
    steps = 0
    while svc.has_work:
        svc.step()
        steps += 1
    assert t.finish_reason == "length"

    last = eng.last_step
    assert last is not None and last["wall_s"] > 0
    assert set(last["phases"]) <= set(schema.PHASES)
    assert "total" in last["phases"]
    # phases nest inside the step: their sum never exceeds the wall time
    parts = sum(v for k, v in last["phases"].items() if k != "total")
    assert parts <= last["phases"]["total"] + 1e-9

    th = svc._phase_hists["total"]
    assert th.count == steps                      # one observation per step
    assert th.sum > 0
    assert svc._ttft_hist.count == 1 and svc._latency_hist.count == 1
    assert svc._latency_hist.sum == pytest.approx(t.latency_s)
    assert svc._ttft_hist.sum == pytest.approx(t.ttft_s)
    # the rendered exposition carries the same numbers
    s = parse_exposition(svc.render_metrics())["samples"]
    assert s[(schema.LATENCY_HISTOGRAM + "_count", ())] == 1
    assert s[(schema.PHASE_HISTOGRAM + "_count",
              (("phase", "total"),))] == steps


def test_spans_one_terminal_under_faults_and_cancel(setup):
    """Chaos-adjacent lifecycle: an injected decode fault and a client
    cancel both land exactly one terminal per uid, and the tiling
    invariant holds on the recorder the engine actually fed."""
    cfg, eng = setup
    rec = eng.tracer = SpanRecorder()
    try:
        now, clk = _ticking_clock()
        svc = Service(eng, ServiceConfig(queue_depth=4), clock=clk)
        h = faults.inject_decode_fault(eng, at=1)
        try:
            a = svc.submit(Request(prompt=_prompts(cfg, [7], seed=3)[0],
                                   max_new_tokens=4))
            b = svc.submit(Request(prompt=_prompts(cfg, [9], seed=3)[0],
                                   max_new_tokens=4))
            while svc.has_work:
                svc.step()
        finally:
            h.restore()
        assert h.fired == 1
        assert a.finish_reason == "error" and b.finish_reason == "error"

        c = svc.submit(Request(prompt=_prompts(cfg, [8], seed=4)[0],
                               max_new_tokens=6))
        svc.step()                                # admit + first chunk
        assert svc.cancel(c.uid)
        svc.drain()

        uids = [a.uid, b.uid, c.uid]
        _lifecycle_ok(rec, uids)
        assert rec.terminals[a.uid] == "error"
        assert rec.terminals[b.uid] == "error"
        assert rec.terminals[c.uid] == "cancelled"
    finally:
        eng.tracer = None


def test_metrics_route_on_front_door(setup):
    cfg, eng = setup
    svc = Service(eng, ServiceConfig(queue_depth=4))
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0)
    prompt = _prompts(cfg, [7], seed=5)[0]

    async def _http(port, method, path, body=b""):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        raw = await reader.read()
        writer.close()
        return raw

    async def scenario():
        await door.start()
        body = json.dumps({"prompt": prompt, "max_new_tokens": 3}).encode()
        raw = await asyncio.wait_for(
            _http(door.port, "POST", "/v1/generate", body), timeout=120)
        assert raw.startswith(b"HTTP/1.1 200")

        raw = await asyncio.wait_for(
            _http(door.port, "GET", "/metrics"), timeout=30)
        head, _, payload = raw.partition(b"\r\n\r\n")
        assert head.startswith(b"HTTP/1.1 200")
        assert b"text/plain" in head and b"version=0.0.4" in head
        await asyncio.wait_for(door.stop(drain=True), timeout=60)
        return payload.decode()

    exposition = asyncio.run(scenario())
    parsed = parse_exposition(exposition)
    assert set(schema.metric_names()) <= set(parsed["types"])
    s = parsed["samples"]
    assert s[(schema.SERVICE_PREFIX + "completed", ())] == 1
    assert s[(schema.LATENCY_HISTOGRAM + "_count", ())] == 1
    assert s[(schema.ENGINE_PREFIX + "accepted_tokens", ())] >= 3


# --------------------------------------------------- lifecycle property
_STORM = {}


def _storm_setup():
    if not _STORM:
        cfg = configs.get_smoke_config(ARCH)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        _STORM["cfg"] = cfg
        _STORM["eng"] = Engine(params, cfg, n_slots=2, max_seq=64,
                               sched=SchedulerConfig(prefill_chunk=8),
                               page_size=8, prefix_cache=False)
    return _STORM["cfg"], _STORM["eng"]


@settings(deadline=None, max_examples=10)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 6)),
                max_size=14))
def test_span_lifecycle_property_under_storm(ops):
    """Random admit / deadline-admit / expiry / cancel / fault
    interleavings: every uid the engine ever saw ends in exactly one
    terminal and queued+active tile its envelope — the recorder never
    loses a request, whatever kills it."""
    cfg, eng = _storm_setup()
    rec = eng.tracer = SpanRecorder()
    fault = None
    try:
        now = [0.0]
        svc = Service(eng, ServiceConfig(queue_depth=3),
                      clock=lambda: now[0])
        rng = np.random.RandomState(23)
        uids = []
        for op, n in ops:
            if op in (0, 1):
                t = svc.submit(
                    Request(prompt=rng.randint(0, cfg.vocab_size,
                                               5 + n).tolist(),
                            max_new_tokens=1 + n % 4),
                    deadline_s=0.5 * (n + 1) if op == 1 else None)
                if t is not None:
                    uids.append(t.uid)
            elif op == 2:
                now[0] += 0.6 * (n + 1)
            elif op == 3 and svc.tickets:
                svc.cancel(sorted(svc.tickets)[n % len(svc.tickets)])
            elif op == 4 and fault is None:
                fault = faults.inject_decode_fault(eng, at=1 + n % 2)
            svc.step()
        svc.drain()
        _lifecycle_ok(rec, uids)
    finally:
        if fault is not None:
            fault.restore()
        eng.tracer = None
