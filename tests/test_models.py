"""Model-component tests: Mamba chunked-vs-sequential, mLSTM chunk invariance,
MoE routing properties, CNN behaviours, data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig
from repro.data.synthetic import SyntheticImages, SyntheticTokens
from repro.models import cnn, moe, ssm, xlstm
from repro.sharding.ctx import default_ctx


# ------------------------------------------------------------------ mamba
def _mamba_cfg(chunk):
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                       ssm=SSMConfig(d_state=8, d_conv=4, expand=2,
                                     chunk=chunk))


def test_mamba_chunk_size_invariance():
    """Chunked parallel scan must not depend on the chunk size."""
    p = ssm.mamba_init(jax.random.PRNGKey(0), _mamba_cfg(64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    y1, _ = ssm.mamba_forward(p, _mamba_cfg(64), x)
    y2, _ = ssm.mamba_forward(p, _mamba_cfg(8), x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_mamba_decode_matches_parallel():
    cfg = _mamba_cfg(16)
    p = ssm.mamba_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32), jnp.float32)
    y_par, _ = ssm.mamba_forward(p, cfg, x)
    state = ssm.init_mamba_state(1, cfg)
    outs = []
    for t in range(16):
        y, state = ssm.mamba_forward(p, cfg, x[:, t:t + 1], state)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------------------------ xlstm
def _xlstm_cfg(chunk):
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                       block_pattern=("mlstm",),
                       xlstm=XLSTMConfig(chunk=chunk))


def test_mlstm_chunk_size_invariance():
    p = xlstm.mlstm_init(jax.random.PRNGKey(0), _xlstm_cfg(64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.bfloat16)
    y1, _ = xlstm.mlstm_forward(p, _xlstm_cfg(64), x)
    y2, _ = xlstm.mlstm_forward(p, _xlstm_cfg(8), x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=6e-2,
                               atol=6e-2)


def test_slstm_state_carries():
    cfg = _xlstm_cfg(8)
    p = xlstm.slstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32), jnp.bfloat16)
    y_full, _ = xlstm.slstm_forward(p, cfg, x)
    st = xlstm.init_slstm_state(1, cfg)
    y1, st = xlstm.slstm_forward(p, cfg, x[:, :6], st)
    y2, st = xlstm.slstm_forward(p, cfg, x[:, 6:], st)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_cat, np.float32),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------------------------ moe
def _moe_cfg(e=4, k=2):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=48, vocab_size=64,
                       moe=MoEConfig(n_experts=e, experts_per_token=k,
                                     capacity_factor=2.0))


def test_moe_routes_and_mixes():
    cfg = _moe_cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.bfloat16)
    ctx = default_ctx()
    out, aux = moe.moe_forward(p, cfg, x, ctx, with_aux=True)
    assert out.shape == x.shape
    assert float(aux["load_balance"]) > 0
    # a token's output depends on its own expert mix: different inputs differ
    x2 = x.at[0, 0].add(1.0)
    out2, _ = moe.moe_forward(p, cfg, x2, ctx, with_aux=False)
    assert float(jnp.max(jnp.abs(out2[0, 0] - out[0, 0]))) > 1e-4


def test_moe_capacity_drops_gracefully():
    cfg = dataclasses.replace(
        _moe_cfg(), moe=MoEConfig(n_experts=4, experts_per_token=2,
                                  capacity_factor=0.1))
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32), jnp.bfloat16)
    out, _ = moe.moe_forward(p, cfg, x, default_ctx())
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


def test_moe_gate_weights_normalized():
    """Scaling every expert by c scales output by ~c (gates sum to 1)."""
    cfg = _moe_cfg()
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.bfloat16)
    out1, _ = moe.moe_forward(p, cfg, x, default_ctx())
    p2 = dict(p, down={"w": p["down"]["w"] * 2})
    out2, _ = moe.moe_forward(p2, cfg, x, default_ctx())
    ratio = (np.asarray(out2, np.float32)
             / (np.asarray(out1, np.float32) + 1e-9))
    assert np.nanmedian(np.abs(ratio)) == pytest.approx(2.0, rel=0.2)


# ------------------------------------------------------------------ cnn
def test_cnn_shapes_and_train_mode():
    for arch in ("resnet18", "mobilenetv3s"):
        cfg = dataclasses.replace(configs.get_cnn_config(arch),
                                  width_mult=0.25)
        v = cnn.cnn_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
        logits, new_st = cnn.cnn_apply(cfg, v, x, train=True)
        assert logits.shape == (2, 10)
        # train mode must update running stats
        diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                            v["stats"], new_st)
        assert max(jax.tree.leaves(diff)) > 0


# ------------------------------------------------------------------ data
def test_synthetic_images_learnable_structure():
    d = SyntheticImages(200, seed=0)
    assert d.images.shape == (200, 32, 32, 3)
    # same-class images correlate more than cross-class
    same = cross = n_same = n_cross = 0.0
    for i in range(0, 50):
        for j in range(i + 1, 50):
            c = float(np.mean(d.images[i] * d.images[j]))
            if d.labels[i] == d.labels[j]:
                same += c
                n_same += 1
            else:
                cross += c
                n_cross += 1
    assert same / n_same > cross / n_cross


def test_synthetic_tokens_markov():
    d = SyntheticTokens(vocab=64, seq_len=33, n_seqs=16, seed=0)
    assert d.seqs.shape == (16, 33)
    assert d.seqs.max() < 64
    b = next(d.batches(4))
    assert b["tokens"].shape == (4, 33)


@given(vocab=st.sampled_from([16, 64]), det=st.floats(0.5, 0.95))
@settings(max_examples=5, deadline=None)
def test_markov_determinism_ceiling(vocab, det):
    d = SyntheticTokens(vocab=vocab, seq_len=200, n_seqs=4, seed=1,
                        determinism=det)
    # empirical top-transition frequency approaches `det`
    from collections import Counter, defaultdict
    trans = defaultdict(Counter)
    for row in d.seqs:
        for a, b in zip(row[:-1], row[1:]):
            trans[int(a)][int(b)] += 1
    tops = [max(c.values()) / sum(c.values()) for c in trans.values()
            if sum(c.values()) >= 20]
    if tops:
        assert abs(np.mean(tops) - det) < 0.2
