"""Sharding rules + a real multi-device SPMD compile (8 forced host devices
in a subprocess, since the test process already initialized 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import lm
from repro.sharding import rules
from repro.sharding.ctx import default_ctx

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_cover_all_leaves():
    for arch in ("qwen3-0.6b", "phi3.5-moe-42b-a6.6b", "jamba-1.5-large-398b",
                 "xlstm-1.3b"):
        cfg = configs.get_smoke_config(arch)
        params = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        specs = rules.param_specs(params, default_ctx())
        leaves_p = jax.tree.leaves(params)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        for lp, ls in zip(leaves_p, leaves_s):
            assert isinstance(ls, P)
            assert len(ls) == lp.ndim


def test_spec_divisibility_guard():
    """A spec whose axis doesn't divide the dim must fall back to replicated."""
    ctx = default_ctx()
    sp = rules.spec_for_path("blocks/0/attn/wq/w", 3, (2, 64, 48), ctx)
    assert isinstance(sp, P)


def test_full_config_specs_divisible_on_production_mesh():
    """Every full-size arch: spec axis sizes divide dims on the 16x16 mesh."""
    from repro.sharding.ctx import RunContext

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        size = 256

    ctx = RunContext(mesh=FakeMesh())   # type: ignore
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        params = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        specs = rules.param_specs(params, ctx)
        for lp, ls in zip(jax.tree.leaves(params),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
            for dim, ax in zip(lp.shape, ls):
                assert dim % rules._axis_size(ctx, ax) == 0, (arch, lp.shape, ls)


@pytest.mark.slow
def test_tiny_mesh_spmd_compile():
    """Real SPMD lower+compile on a forced 2x4 host-device mesh (subprocess)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import lm
        from repro.sharding import rules
        from repro.sharding.ctx import make_ctx
        from repro.train.optimizer import AdamWConfig, adamw_init
        from repro.train.train_step import make_train_step
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        cfg = configs.get_smoke_config("phi3.5-moe-42b-a6.6b")
        ctx = make_ctx(mesh, batch_sharded=True)
        params = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = rules.param_shardings(params, ctx)
        opt_cfg = AdamWConfig()
        opt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
        mk = lambda s: jax.tree.map(lambda x: NamedSharding(mesh, x), s,
                                    is_leaf=lambda x: isinstance(x, P))
        o_sh = mk(rules.opt_state_specs(params, opt, ctx))
        b_sh = mk(rules.batch_specs(cfg, ctx))
        step = make_train_step(cfg, ctx, opt_cfg)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        with mesh:
            compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                               donate_argnums=(0, 1)).lower(
                params, opt, batch).compile()
        txt = compiled.as_text()
        assert "all-reduce" in txt or "all-gather" in txt
        print("TINY_MESH_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "TINY_MESH_OK" in out.stdout, out.stderr[-3000:]
