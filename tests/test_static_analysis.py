"""Static-analysis subsystem: both planes, seeded violations first.

The acceptance bar for ``repro.analysis`` is NOT "the real tree passes" —
a checker that cannot fail is decoration. Every compiled-plane check is
exercised against a fixture callable seeded with the exact historical bug
class it exists to catch (the §12 f32 DUS sandwich, a dropped donation,
a hidden host callback, an unbounded retrace), and every AST rule against
a known-bad and a known-good snippet plus the inline disable escape
hatch. The real tree passing ``check_static.py`` is then the LAST
assertion, not the only one.
"""
import dataclasses
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import astlint, hlo_core, hlo_checks
from repro.analysis.invariants import (REGISTRY, declare_invariants,
                                       spec_of)
from repro.analysis.report import render

ROOT = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------------- hlo_core
def _dus_fn(cache, upd, i):
    return jax.lax.dynamic_update_slice(cache, upd, (i, jnp.int32(0)))


def test_hlo_core_parses_instructions_across_computations():
    jf = jax.jit(_dus_fn, donate_argnums=(0,))
    text = jf.lower(jnp.zeros((16, 32), jnp.float32),
                    jnp.zeros((1, 32), jnp.float32),
                    jnp.int32(0)).compile().as_text()
    instrs = hlo_core.parse_instructions(text)
    assert instrs, "parser produced nothing from a real compiled dump"
    dus = [i for i in instrs if i.opcode == "dynamic-update-slice"]
    assert dus, "dynamic-update-slice not found (fusion bodies walked?)"
    assert any(i.dims == (16, 32) and i.dtype == "f32" for i in dus)
    # operand linkage: every instruction's operands name other results
    by_name = hlo_core.index_by_name(instrs)
    assert any(o in by_name for i in instrs for o in i.operands)


def test_hlo_core_alias_map_roundtrip():
    donating = jax.jit(_dus_fn, donate_argnums=(0,))
    plain = jax.jit(_dus_fn)
    args = (jnp.zeros((8, 4), jnp.float32), jnp.zeros((1, 4), jnp.float32),
            jnp.int32(0))
    t_d = donating.lower(*args).compile().as_text()
    t_p = plain.lower(*args).compile().as_text()
    assert hlo_core.aliased_param_numbers(t_d)
    assert not hlo_core.aliased_param_numbers(t_p)
    params = hlo_core.parse_entry_params(t_d)
    assert "f32[8,4]" in params and "f32[1,4]" in params


# ------------------------------ seeded violations, one per check (§15)
def test_f32_roundtrip_detector_fires_on_bf16_store():
    """The §12 bug class as a fixture: a plain bf16 cache DUS lowers on
    XLA CPU through float-normalization f32 converts — the checker must
    flag it against the declared cache size."""
    cache = jnp.zeros((16, 32), jnp.bfloat16)
    fn = declare_invariants(
        "fixture.bf16_store", host_syncs=1,
        forbid_f32_roundtrip_on=("kv",))(
        jax.jit(_dus_fn, donate_argnums=(0,)))
    v = hlo_checks.check_callable(
        fn, (cache, jnp.zeros((1, 32), jnp.bfloat16), jnp.int32(0)),
        where="fixture.bf16_store", protected_counts=[cache.size])
    assert [x.rule for x in v] == ["f32-roundtrip"], render(v)


def test_f32_roundtrip_passes_uint16_store():
    """The PR 6/8 fix pattern — bf16 bit patterns stored as raw uint16
    words (kernels.kv_layout.to_store) — must pass the same check."""
    def store(cache, upd, i):
        raw = jax.lax.bitcast_convert_type(upd, jnp.uint16)
        return jax.lax.dynamic_update_slice(cache, raw, (i, jnp.int32(0)))
    cache = jnp.zeros((16, 32), jnp.uint16)
    fn = declare_invariants(
        "fixture.u16_store", host_syncs=1,
        forbid_f32_roundtrip_on=("kv",))(
        jax.jit(store, donate_argnums=(0,)))
    v = hlo_checks.check_callable(
        fn, (cache, jnp.zeros((1, 32), jnp.bfloat16), jnp.int32(0)),
        where="fixture.u16_store", protected_counts=[cache.size])
    assert v == [], render(v)


def test_donation_check_fires_when_donation_removed():
    def bump(pool, x):
        return {k: v + x for k, v in pool.items()}
    pool = {"a": jnp.zeros((4,), jnp.float32),
            "b": jnp.zeros((2, 3), jnp.float32)}
    x = jnp.ones((), jnp.float32)
    # donation declared but the jit forgot donate_argnums: both leaves flag
    broken = declare_invariants("fixture.nodonate", donated=("pool",))(
        jax.jit(bump))
    v = hlo_checks.check_callable(broken, (pool, x),
                                  where="fixture.nodonate")
    assert {x_.rule for x_ in v} == {"donation"} and len(v) == 2, render(v)
    # with donate_argnums present the same declaration passes
    ok = declare_invariants("fixture.donate", donated=("pool",))(
        jax.jit(bump, donate_argnums=(0,)))
    assert hlo_checks.check_callable(ok, (pool, x),
                                     where="fixture.donate") == []


def test_host_sync_check_fires_on_hidden_callback():
    def leaky(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2
    fn = declare_invariants("fixture.sync", host_syncs=1)(jax.jit(leaky))
    v = hlo_checks.check_callable(fn, (jnp.zeros((4,), jnp.float32),),
                                  where="fixture.sync")
    assert [x.rule for x in v] == ["host-syncs"], render(v)
    clean = declare_invariants("fixture.nosync", host_syncs=1)(
        jax.jit(lambda x: x * 2))
    assert hlo_checks.check_callable(
        clean, (jnp.zeros((4,), jnp.float32),), where="fixture.nosync") == []


# ----------------------------------------------- live-engine scenarios
@pytest.fixture(scope="module")
def engine():
    return hlo_checks.build_scenario(quantized_kv=False, paged=False)


def test_real_engine_hot_paths_pass_all_checks(engine):
    v = hlo_checks.check_engine(engine, "bf16+contig")
    assert v == [], render(v)


def test_retrace_budget_fires_on_seeded_bound(engine):
    """Drive the scripted workload, then shrink the decode path's declared
    budget to 0 on the live callable — the check must fire; restoring the
    real window-bucketing bound must pass."""
    real = spec_of(engine._decode_fn)
    assert real is not None and real.max_lowerings is not None
    v = hlo_checks.check_retrace(engine, "bf16+contig")
    assert v == [], render(v)        # real bound holds after the workload
    engine._decode_fn.__repro_invariants__ = dataclasses.replace(
        real, max_lowerings=0)
    try:
        v = hlo_checks.check_retrace(engine, "bf16+contig")
        assert [x.rule for x in v] == ["retrace-budget"], render(v)
    finally:
        engine._decode_fn.__repro_invariants__ = real


def test_registry_records_engine_declarations(engine):
    for name in ("engine.reset", "engine.prefill", "engine.decode"):
        assert name in REGISTRY, sorted(REGISTRY)
        assert REGISTRY[name].host_syncs == 1
        assert "pool" in REGISTRY[name].donated
    assert spec_of(engine._decode_fn).donated_positions() == (1,)


def test_declare_invariants_rejects_unknown_arg():
    with pytest.raises(ValueError):
        declare_invariants("fixture.bad", donated=("nope",))(
            lambda pool: pool)


# ------------------------------------------------------------ AST lint
_SERVING = "src/repro/serving/service.py"

_CLOCK_BAD = """
import time

class Service:
    def __init__(self, clock=time.monotonic):
        self.clock = clock

    def step(self):
        return time.time()
"""

_CLOCK_GOOD = _CLOCK_BAD.replace("time.time()", "self.clock()")

_CLOCK_DISABLED = _CLOCK_BAD.replace(
    "time.time()", "time.time()  # repro-lint: disable=no-raw-clock")


def test_no_raw_clock_fires_in_service_fixture():
    """The seeded AST violation from the issue: time.time() in a
    service.py that declares an injectable clock."""
    v = astlint.lint_source(_CLOCK_BAD, _SERVING)
    assert [x.rule for x in v] == ["no-raw-clock"]
    assert astlint.lint_source(_CLOCK_GOOD, _SERVING) == []


def test_no_raw_clock_inline_disable():
    assert astlint.lint_source(_CLOCK_DISABLED, _SERVING) == []


def test_no_raw_clock_skips_modules_without_clock_param():
    src = "import time\n\ndef tick():\n    return time.monotonic()\n"
    assert astlint.lint_source(src, _SERVING) == []


_PUMP_BAD = """
class Door:
    async def _handle(self, req):
        n = self.service.engine.max_seq          # read: allowed
        self.service.engine.submit(req)          # call: pump-owned!

    def _pump(self):
        self.service.step()                      # sync pump thread: fine
"""

_PUMP_GOOD = """
class Door:
    async def _handle(self, req):
        n = self.service.engine.max_seq
        self._inbox.append(("submit", req))
        return await self._ask("stats")
"""


def test_pump_single_owner_rule():
    v = astlint.lint_source(_PUMP_BAD, _SERVING)
    assert [x.rule for x in v] == ["pump-single-owner"]
    assert "submit" in v[0].message
    assert astlint.lint_source(_PUMP_GOOD, _SERVING) == []


_HOT_BAD = """
import jax
import numpy as np

def _decode(pool, tok):
    n = int(tok.sum())                 # host sync inside the hot path
    return np.asarray(pool), n

_decode_fn = jax.jit(_decode, donate_argnums=(0,))
"""

_HOT_GOOD = """
import jax
import numpy as np

def _decode(pool, tok):
    return pool, tok * 2

_decode_fn = jax.jit(_decode, donate_argnums=(0,))

def harvest(out):
    return int(np.asarray(out).sum())  # outside jit: fine
"""


def test_no_host_sync_in_hot_path_rule():
    v = astlint.lint_source(_HOT_BAD, "src/repro/serving/engine.py")
    assert {x.rule for x in v} == {"no-host-sync-in-hot-path"}
    assert len(v) == 2                 # int() and np.asarray
    assert astlint.lint_source(_HOT_GOOD,
                               "src/repro/serving/engine.py") == []


_BENCH_BAD = "def gate(x):\n    assert x < 2.0\n"
_BENCH_GOOD = ("def gate(x):\n"
               "    assert x < 2.0, f'flat ratio {x:.2f} above 2.0'\n")


def test_bench_gate_message_rule():
    v = astlint.lint_source(_BENCH_BAD, "scripts/check_bench.py")
    assert [x.rule for x in v] == ["bench-gate-message"]
    assert astlint.lint_source(_BENCH_GOOD, "scripts/check_bench.py") == []
    # the rule is scoped to check_bench.py — test files keep bare asserts
    assert astlint.lint_source(_BENCH_BAD, "tests/test_foo.py") == []


_DUP_BAD = """
import numpy as np

def first_token(row):
    return int(np.argmax(np.asarray(row)))

def pick(row):
    return int(np.argmax(np.asarray(row)))
"""

_DUP_GOOD = """
import numpy as np

def _pick_token(row):
    return int(np.argmax(np.asarray(row)))

def first_token(row):
    return _pick_token(row)
"""


def test_duplicate_hot_path_helper_rule():
    v = astlint.lint_source(_DUP_BAD, "src/repro/serving/engine.py")
    assert {x.rule for x in v} == {"duplicate-hot-path-helper"}
    assert len(v) == 2                 # flagged at both sites
    assert astlint.lint_source(_DUP_GOOD,
                               "src/repro/serving/engine.py") == []


_STATS_BAD = """
class Service:
    def __init__(self):
        self.stats = {"submitted": 0, "not_a_real_key": 0}

    def step(self):
        self.stats["another_rogue"] += 1
"""

_STATS_GOOD = """
class Service:
    def __init__(self):
        self.stats = {"submitted": 0, "completed": 0}

    def step(self):
        self.stats["completed"] += 1
        for k in self.stats:          # variable keys: bench-style resets
            self.stats[k] = 0
"""

_STATS_DISABLED = _STATS_BAD.replace(
    'self.stats["another_rogue"] += 1',
    'self.stats["another_rogue"] += 1'
    '  # repro-lint: disable=stats-schema')


def test_stats_schema_fires_on_undeclared_key():
    """The seeded violation: a serving stats key that never made it into
    repro.telemetry.schema would silently fall off GET /metrics."""
    v = astlint.lint_source(_STATS_BAD, _SERVING)
    assert [x.rule for x in v] == ["stats-schema", "stats-schema"]
    assert "not_a_real_key" in v[0].message
    assert "another_rogue" in v[1].message
    assert astlint.lint_source(_STATS_GOOD, _SERVING) == []


def test_stats_schema_inline_disable_and_scope():
    v = astlint.lint_source(_STATS_DISABLED, _SERVING)
    # the dict-literal rogue key still fires; the disabled line does not
    assert [x.rule for x in v] == ["stats-schema"]
    assert "not_a_real_key" in v[0].message
    # scoped to serving/: bench code keeps ad-hoc result dicts
    assert astlint.lint_source(_STATS_BAD, "benchmarks/run.py") == []


# -------------------------------------------------- real tree + driver
def test_astlint_real_tree_clean():
    v = astlint.lint_tree(ROOT)
    assert v == [], render(v)


def test_check_static_driver_ast_plane(monkeypatch, capsys):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_static", ROOT / "scripts" / "check_static.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    monkeypatch.setattr(sys, "argv", ["check_static.py", "--plane", "ast"])
    assert mod.main() == 0
    out = capsys.readouterr().out
    assert "OK (0 violations)" in out
