"""Fault tolerance: atomic checkpoints, torn-write detection, auto-resume,
elastic restore, straggler policy."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import checkpoint as ckpt
from repro.launch.elastic import StragglerPolicy, choose_mesh_shape


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "blocks": (jnp.arange(6.0).reshape(2, 3),)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    restored, meta = ckpt.restore(str(tmp_path), t)
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 4
    ckpt.prune_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert not (tmp_path / "step_000000001").exists()


def test_torn_checkpoint_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a node dying mid-write at step 2: no commit marker
    torn = tmp_path / "step_000000002"
    torn.mkdir()
    (torn / "meta.json").write_text("{}")
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, meta = ckpt.restore(str(tmp_path), t)
    assert meta["step"] == 1


def test_restore_resharded(tmp_path):
    """Restore with explicit shardings (elastic re-mesh path)."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), t)
    restored, _ = ckpt.restore(str(tmp_path), t, shardings=sh)
    assert restored["params"]["w"].sharding.is_fully_replicated


def test_resume_exact_training(tmp_path):
    """Train 4 steps, checkpoint at 2, resume -> identical params at 4."""
    from repro import configs
    from repro.models import lm
    from repro.sharding.ctx import default_ctx
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step
    cfg = configs.get_smoke_config("stablelm-1.6b")
    ctx = default_ctx()
    opt_cfg = AdamWConfig(lr=1e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, ctx, opt_cfg))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 16),
                                             0, cfg.vocab_size)}
               for i in range(4)]
    p, o = params, opt
    for i in range(2):
        p, o, _ = step(p, o, batches[i])
    ckpt.save(str(tmp_path), 2, (p, o))
    for i in range(2, 4):
        p, o, _ = step(p, o, batches[i])
    # crash + resume
    (p2, o2), meta = ckpt.restore(str(tmp_path), (params, opt))
    for i in range(meta["step"], 4):
        p2, o2, _ = step(p2, o2, batches[i])
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_choose_mesh_shape_elastic():
    assert choose_mesh_shape(256, 16, 256) == (16, 16)
    # lose a host (8 chips): 248 // 16 = 15 -> data=8 divides 256
    data, model = choose_mesh_shape(248, 16, 256)
    assert data * model <= 248 and 256 % data == 0


def test_straggler_policy():
    pol = StragglerPolicy(threshold=1.5, patience=3)
    times = {f"d{i}": 1.0 for i in range(8)}
    times["d3"] = 2.5
    dropped = []
    for _ in range(3):
        dropped = pol.observe(times)
    assert dropped == ["d3"]
    # healthy device never dropped
    pol2 = StragglerPolicy(patience=2)
    assert pol2.observe({f"d{i}": 1.0 for i in range(4)}) == []
