"""bench_diff.py one-sided population changes must annotate, not crash.

The diff script walks the intersection of baseline and new bench data for
regressions; names present on only ONE side used to vanish silently. A
removed variant is exactly the failure mode the trajectory view exists to
surface (a bench that quietly stopped running), so both directions now
print notice-level annotations — and always exit 0, because population
changes are usually the PR's whole point.
"""
import importlib.util
import json
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def bench_diff():
    spec = importlib.util.spec_from_file_location(
        "bench_diff", ROOT / "scripts" / "bench_diff.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench(rows=(), variants=None):
    return {"rows": list(rows),
            "serving": {"variants": dict(variants or {})}}


def _run(bench_diff, tmp_path, new, base):
    n, b = tmp_path / "new.json", tmp_path / "base.json"
    n.write_text(json.dumps(new))
    b.write_text(json.dumps(base))
    return bench_diff.main([str(n), str(b)])


def test_new_variant_annotated(bench_diff, tmp_path, capsys):
    new = _bench(variants={"batched": {"tokens_per_s": 100.0},
                           "paged": {"tokens_per_s": 90.0}})
    base = _bench(variants={"batched": {"tokens_per_s": 100.0}})
    assert _run(bench_diff, tmp_path, new, base) == 0
    out = capsys.readouterr().out
    assert "::notice::serving/paged: new variant" in out
    assert "::warning::" not in out


def test_removed_variant_annotated(bench_diff, tmp_path, capsys):
    new = _bench(variants={"batched": {"tokens_per_s": 100.0}})
    base = _bench(variants={"batched": {"tokens_per_s": 100.0},
                            "speculative": {"tokens_per_s": 140.0}})
    assert _run(bench_diff, tmp_path, new, base) == 0
    out = capsys.readouterr().out
    assert "::notice::serving/speculative: variant removed" in out
    assert "::warning::" not in out


def test_new_and_removed_rows_annotated(bench_diff, tmp_path, capsys):
    new = _bench(rows=[{"name": "decode_bf16", "us_per_call": 10.0},
                       {"name": "decode_int8", "us_per_call": 8.0}])
    base = _bench(rows=[{"name": "decode_bf16", "us_per_call": 10.0},
                        {"name": "prefill_bf16", "us_per_call": 55.0}])
    assert _run(bench_diff, tmp_path, new, base) == 0
    out = capsys.readouterr().out
    assert "::notice::decode_int8: new row" in out
    assert "::notice::prefill_bf16: row removed (was 55.0us" in out
    assert "::warning::" not in out


def test_shared_names_still_diffed_alongside_one_sided(
        bench_diff, tmp_path, capsys):
    # a one-sided entry must not mask a genuine regression on shared names
    new = _bench(rows=[{"name": "decode", "us_per_call": 20.0},
                       {"name": "fresh", "us_per_call": 1.0}],
                 variants={"batched": {"tokens_per_s": 50.0}})
    base = _bench(rows=[{"name": "decode", "us_per_call": 10.0}],
                  variants={"batched": {"tokens_per_s": 100.0},
                            "gone": {"tokens_per_s": 1.0}})
    assert _run(bench_diff, tmp_path, new, base) == 0
    out = capsys.readouterr().out
    assert "::warning::decode slowed: 10.0us -> 20.0us" in out
    assert "::warning::serving/batched tokens/s regressed" in out
    assert "::notice::fresh: new row" in out
    assert "::notice::serving/gone: variant removed" in out


def test_identical_benches_quiet(bench_diff, tmp_path, capsys):
    b = _bench(rows=[{"name": "decode", "us_per_call": 10.0}],
               variants={"batched": {"tokens_per_s": 100.0}})
    assert _run(bench_diff, tmp_path, b, b) == 0
    out = capsys.readouterr().out
    assert "::notice::" not in out and "::warning::" not in out
    assert "no regressions" in out
