"""Service layer: bounded admission, deadlines, shed, drain, SSE transport.

The load-bearing guarantees (DESIGN.md §13, §14):
  * shed fires EXACTLY at queue+slot saturation (load == n_slots +
    queue_depth) and releases as soon as a request finishes;
  * a deadline expiry evicts the request wherever it lives — queued or
    MID-PREFILL — and, in paged mode, returns the allocator's refcounts to
    baseline immediately (no page leak, no slot leak);
  * drain completes every already-admitted request while shedding new ones;
  * tokens streamed through the service are IDENTICAL to ``Engine.run`` on
    the same requests, and the sink sees them one at a time, in order;
  * the HTTP loopback speaks well-formed SSE (token events then exactly one
    done event), answers /healthz, and 400s malformed bodies;
  * an injected per-request fault (``serving.faults``) errors exactly the
    requests it hit — pages freed, ``event: error`` on their streams —
    while the pump keeps serving, post-fault tokens stay identical, and a
    wedged pump escalates through the watchdog;
  * the front door hardens the socket edge: non-POST generate -> 400,
    oversized body -> 413 (body never read), slow-loris -> 408;
  * random admit/cancel/deadline-expiry storms (hypothesis) always return
    the page allocator to baseline.
"""
import asyncio
import json
import threading
import time

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.models import lm
from repro.serving import (Engine, HttpFrontDoor, Request, SchedulerConfig,
                           Service, ServiceConfig, faults)

ARCH = "qwen3-0.6b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]


def _fake_clock():
    now = [0.0]
    return now, (lambda: now[0])


# ----------------------------------------------------------------- admission
def test_shed_exactly_at_saturation(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    svc = Service(eng, ServiceConfig(queue_depth=1))
    assert svc.capacity == 2
    reqs = [Request(prompt=p, max_new_tokens=2)
            for p in _prompts(cfg, [6, 6, 6, 6])]

    a = svc.submit(reqs[0])
    b = svc.submit(reqs[1])
    assert a is not None and b is not None     # below the bound: admitted
    assert svc.submit(reqs[2]) is None         # AT the bound: shed
    assert svc.stats["shed"] == 1 and svc.stats["submitted"] == 2

    while not a.done:                          # finish one...
        svc.step()
    c = svc.submit(reqs[3])                    # ...and the bound releases
    assert c is not None and svc.stats["shed"] == 1
    while svc.has_work:
        svc.step()
    assert b.done and c.done
    assert svc.stats["completed"] == 3 and not svc.tickets


def test_deadline_evicts_queued_and_mid_prefill_frees_pages(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=4),
                 page_size=8, prefix_cache=False)
    base = eng.alloc.pages_in_use
    now, clock = _fake_clock()
    svc = Service(eng, ServiceConfig(queue_depth=2), clock=clock)
    p_long, p_short = _prompts(cfg, [16, 8], seed=3)
    a = svc.submit(Request(prompt=p_long, max_new_tokens=4), deadline_s=5.0)
    b = svc.submit(Request(prompt=p_short, max_new_tokens=4), deadline_s=5.0)

    svc.step()       # admit A, prefill ONE 4-token chunk of its 16; B queued
    assert eng.n_active == 1 and len(eng.waiting) == 1
    assert not a.done and not a.tokens         # genuinely mid-prefill
    assert eng.alloc.pages_in_use > base       # holding pages already

    now[0] = 100.0                             # both deadlines blow
    svc.step()
    assert a.finish_reason == "deadline"       # evicted out of its slot
    assert b.finish_reason == "deadline"       # dropped from the queue
    assert eng.n_active == 0 and not eng.waiting and not eng.has_work
    assert eng.alloc.pages_in_use == base      # refcounts back to baseline
    eng.alloc.check()
    assert svc.stats["expired"] == 2 and eng.stats["cancelled"] == 2

    # the slot is genuinely reusable after the eviction
    c = svc.submit(Request(prompt=p_short, max_new_tokens=2))
    while svc.has_work:
        svc.step()
    assert c.finish_reason == "length" and len(c.tokens) == 2
    assert eng.alloc.pages_in_use == base
    eng.alloc.check()


def test_drain_completes_all_admitted_and_sheds_new(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    svc = Service(eng, ServiceConfig(queue_depth=4))
    tickets = [svc.submit(Request(prompt=p, max_new_tokens=3))
               for p in _prompts(cfg, [6, 7, 8, 9], seed=5)]
    assert all(t is not None for t in tickets)
    svc.drain()
    assert all(t.finish_reason == "length" and len(t.tokens) == 3
               for t in tickets)
    assert svc.stats["completed"] == 4 and not svc.has_work
    assert svc.submit(Request(prompt=[1, 2, 3], max_new_tokens=2)) is None
    assert svc.draining and svc.stats["shed"] == 1


# ------------------------------------------------------------ token identity
def test_streamed_tokens_identical_to_engine_run(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    reqs = [Request(prompt=p, max_new_tokens=6)
            for p in _prompts(cfg, [5, 9, 13], seed=7)]
    svc = Service(eng, ServiceConfig(queue_depth=4))
    events = {i: [] for i in range(len(reqs))}
    tickets = [svc.submit(r, sink=events[i].append)
               for i, r in enumerate(reqs)]
    while svc.has_work:
        svc.step()

    ref = eng.run(reqs)     # same engine => same compiled fns, fresh replay
    for i, t in enumerate(tickets):
        assert t.tokens == ref[i].tokens
        toks = [e for e in events[i] if e[0] == "token"]
        dones = [e for e in events[i] if e[0] == "done"]
        # streamed one at a time, in order, then exactly one done
        assert [e[1] for e in toks] == list(range(6))
        assert [e[2] for e in toks] == t.tokens
        assert len(dones) == 1 and events[i][-1] is dones[0]
        assert dones[0][1]["finish_reason"] == "length"
        assert dones[0][1]["n_tokens"] == 6


# ------------------------------------------------------------- HTTP loopback
async def _http(port, method, path, body=b""):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    raw = await reader.read()          # Connection: close => read to EOF
    writer.close()
    return raw


def _parse_sse(raw: bytes):
    head, _, payload = raw.partition(b"\r\n\r\n")
    events = []
    for block in payload.decode().strip().split("\n\n"):
        lines = dict(line.split(": ", 1) for line in block.splitlines())
        events.append((lines["event"], json.loads(lines["data"])))
    return head.decode(), events


def test_http_sse_loopback(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    prompt = _prompts(cfg, [7], seed=9)[0]
    ref = eng.run([Request(prompt=prompt, max_new_tokens=4)])[0].tokens
    svc = Service(eng, ServiceConfig(queue_depth=4))
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0)

    async def scenario():
        await door.start()
        body = json.dumps({"prompt": prompt, "max_new_tokens": 4}).encode()
        raw = await asyncio.wait_for(
            _http(door.port, "POST", "/v1/generate", body), timeout=120)
        head, events = _parse_sse(raw)
        assert head.startswith("HTTP/1.1 200")
        assert "text/event-stream" in head
        assert [name for name, _ in events] == ["token"] * 4 + ["done"]
        assert [d["token"] for name, d in events if name == "token"] == ref
        done = events[-1][1]
        assert done["finish_reason"] == "length" and done["n_tokens"] == 4
        assert done["latency_ms"] is not None

        raw = await asyncio.wait_for(
            _http(door.port, "GET", "/healthz"), timeout=30)
        head, _, payload = raw.partition(b"\r\n\r\n")
        health = json.loads(payload)
        assert head.decode().startswith("HTTP/1.1 200")
        assert health["status"] == "ok"
        assert health["service"]["completed"] == 1

        raw = await asyncio.wait_for(
            _http(door.port, "POST", "/v1/generate", b"{not json"),
            timeout=30)
        assert raw.decode().startswith("HTTP/1.1 400")

        await asyncio.wait_for(door.stop(drain=True), timeout=60)

    asyncio.run(scenario())
    assert svc.stats["completed"] == 1 and not svc.has_work


# ------------------------------------------------------------ fault isolation
def test_decode_fault_errors_requests_pump_survives(setup):
    """A decode-dispatch fault errors exactly the in-flight batch: pages
    freed, ``faults`` counted, streams finished with ``error`` — and the
    very next submit on the SAME service completes with identical tokens
    (the blast radius never reaches the pump or the pools)."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8),
                 page_size=8, prefix_cache=False)
    prompts = _prompts(cfg, [7, 9, 11], seed=11)
    ref = eng.run([Request(prompt=prompts[2], max_new_tokens=4)])[0].tokens
    svc = Service(eng, ServiceConfig(queue_depth=4))
    events = []
    h = faults.inject_decode_fault(eng, at=1)
    try:
        a = svc.submit(Request(prompt=prompts[0], max_new_tokens=4),
                       sink=events.append)
        b = svc.submit(Request(prompt=prompts[1], max_new_tokens=4))
        while svc.has_work:        # must terminate: the pump absorbs it
            svc.step()
    finally:
        h.restore()
    assert h.fired == 1
    assert a.finish_reason == "error" and b.finish_reason == "error"
    assert events[-1][0] == "done"
    assert events[-1][1]["finish_reason"] == "error"
    assert svc.stats["faults"] == 2 and eng.stats["faults"] == 2
    assert eng.alloc.pages_in_use == 0     # no page outlives its request
    eng.alloc.check()

    c = svc.submit(Request(prompt=prompts[2], max_new_tokens=4))
    while svc.has_work:
        svc.step()
    assert c.finish_reason == "length" and c.tokens == ref
    assert eng.alloc.pages_in_use == 0
    eng.alloc.check()


def test_alloc_fault_fails_only_that_admission(setup):
    """Page-allocator exhaustion at admit errors the request being mapped
    — and ONLY it; a request admitted after the fault window completes."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8),
                 page_size=8, prefix_cache=False)
    prompts = _prompts(cfg, [9, 9], seed=13)
    svc = Service(eng, ServiceConfig(queue_depth=4))
    h = faults.inject_alloc_failure(eng, at=1)
    try:
        a = svc.submit(Request(prompt=prompts[0], max_new_tokens=3))
        while svc.has_work:
            svc.step()
    finally:
        h.restore()
    assert h.fired == 1 and a.finish_reason == "error"
    assert svc.stats["faults"] == 1
    b = svc.submit(Request(prompt=prompts[1], max_new_tokens=3))
    while svc.has_work:
        svc.step()
    assert b.finish_reason == "length" and len(b.tokens) == 3
    assert eng.alloc.pages_in_use == 0
    eng.alloc.check()


def test_http_stream_gets_error_event(setup):
    """A faulted request's SSE stream terminates with ``event: error``
    (same payload shape as ``done``) — a 200 stream never just drops."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8),
                 page_size=8, prefix_cache=False)
    prompt = _prompts(cfg, [7], seed=15)[0]
    svc = Service(eng, ServiceConfig(queue_depth=2))
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0)
    h = faults.inject_decode_fault(eng, at=1)

    async def scenario():
        await door.start()
        body = json.dumps({"prompt": prompt, "max_new_tokens": 4}).encode()
        raw = await asyncio.wait_for(
            _http(door.port, "POST", "/v1/generate", body), timeout=120)
        head, events = _parse_sse(raw)
        assert head.startswith("HTTP/1.1 200")
        assert events[-1][0] == "error"
        assert events[-1][1]["finish_reason"] == "error"
        await asyncio.wait_for(door.stop(drain=True), timeout=60)

    try:
        asyncio.run(scenario())
    finally:
        h.restore()
    assert svc.stats["faults"] == 1 and eng.alloc.pages_in_use == 0
    eng.alloc.check()


# -------------------------------------------------------------- HTTP hardening
def test_http_front_door_hardening(setup):
    """Socket-edge attacks each get their own clean status without ever
    touching the pump: non-POST generate -> 400, oversized body -> 413
    (judged from Content-Length, body never read), invalid prompt shapes
    -> 400, slow-loris -> 408."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    svc = Service(eng, ServiceConfig(queue_depth=2))
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0,
                         max_body_bytes=256, request_timeout_s=0.3)

    async def scenario():
        await door.start()
        raw = await _http(door.port, "GET", "/v1/generate")
        assert raw.startswith(b"HTTP/1.1 400")
        assert b"use POST" in raw

        # content-length over the cap: refused before any body bytes move
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       door.port)
        writer.write(b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                     b"Content-Length: 999999\r\n\r\n")
        await writer.drain()
        raw = await reader.read()
        writer.close()
        assert raw.startswith(b"HTTP/1.1 413")

        for bad in ({"prompt": "not a list"},
                    {"prompt": [1, "x"]},
                    {"prompt": []},
                    {"prompt": [1, 2], "max_new_tokens": 0},
                    {"prompt": [1] * 60, "max_new_tokens": 60}):  # > max_seq
            raw = await _http(door.port, "POST", "/v1/generate",
                              json.dumps(bad).encode())
            assert raw.startswith(b"HTTP/1.1 400"), bad

        # slow-loris: a partial request line, then silence
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       door.port)
        writer.write(b"POST /v1/gen")
        await writer.drain()
        raw = await asyncio.wait_for(reader.read(), timeout=10)
        writer.close()
        assert raw.startswith(b"HTTP/1.1 408")

        await asyncio.wait_for(door.stop(drain=True), timeout=60)

    asyncio.run(scenario())
    assert svc.stats["submitted"] == 0     # nothing ever reached admission


# ------------------------------------------------------------------- watchdog
def test_watchdog_fires_on_stale_heartbeat(setup):
    """The watchdog judges only the pump heartbeat: a stale ``_beat``
    fires ``on_wedged`` (injected recorder here; the default logs and
    ``os._exit(2)``s) and the thread returns."""
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    svc = Service(eng, ServiceConfig(queue_depth=1))
    rec = []
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0, watchdog_s=0.05,
                         on_wedged=rec.append)
    door._beat = time.monotonic() - 10.0   # simulate a wedged engine step
    t = threading.Thread(target=door._watch)
    t.start()
    t.join(timeout=5)
    assert not t.is_alive()
    assert len(rec) == 1 and "WATCHDOG" in rec[0]
    # a fresh beat never fires (generous threshold: no scheduler jitter
    # can make this flake), and stop terminates the thread cleanly
    rec.clear()
    door.watchdog_s = 5.0
    door._beat = time.monotonic()
    stopper = threading.Thread(target=door._watch)
    stopper.start()
    time.sleep(0.05)
    door._stop_pump.set()
    stopper.join(timeout=10)
    assert not stopper.is_alive() and not rec


def test_watchdog_default_escalation_is_exit(setup):
    cfg, params = setup
    eng = Engine(params, cfg, n_slots=1, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    svc = Service(eng, ServiceConfig(queue_depth=1))
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0, watchdog_s=60.0)
    assert door.on_wedged == door._exit_wedged


# ------------------------------------------------------------ allocator storms
_STORM = {}


def _storm_engine():
    """One compiled engine shared across hypothesis examples (fresh
    Service per example; every example drains fully, so examples are
    independent given the leak assertions hold — which is the property)."""
    if not _STORM:
        cfg = configs.get_smoke_config(ARCH)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        _STORM["cfg"] = cfg
        _STORM["eng"] = Engine(params, cfg, n_slots=2, max_seq=64,
                               sched=SchedulerConfig(prefill_chunk=8),
                               page_size=8, prefix_cache=False)
    return _STORM["cfg"], _STORM["eng"]


@settings(deadline=None, max_examples=12)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 6)),
                max_size=16))
def test_service_storm_pages_return_to_baseline(ops):
    """Random interleavings of admit / deadline-admit / clock-jump
    (expiry) / cancel (disconnect), stepping between ops: whatever the
    sequence, draining returns the allocator to zero pages in use with
    intact refcount invariants, and every ticket reaches a terminal
    state. This is the host-side shape of a client storm — the property
    the HTTP chaos smoke asserts over real sockets."""
    cfg, eng = _storm_engine()
    now = [0.0]
    svc = Service(eng, ServiceConfig(queue_depth=3), clock=lambda: now[0])
    rng = np.random.RandomState(17)
    tickets = []
    for op, n in ops:
        if op == 0:                        # plain admit
            t = svc.submit(Request(
                prompt=rng.randint(0, cfg.vocab_size, 5 + n).tolist(),
                max_new_tokens=1 + n % 4))
            if t is not None:
                tickets.append(t)
        elif op == 1:                      # deadlined admit
            t = svc.submit(Request(
                prompt=rng.randint(0, cfg.vocab_size, 5 + n).tolist(),
                max_new_tokens=1 + n % 4), deadline_s=0.5 * (n + 1))
            if t is not None:
                tickets.append(t)
        elif op == 2:                      # clock jump: deadlines blow
            now[0] += 0.6 * (n + 1)
        elif op == 3 and svc.tickets:      # disconnect a live request
            uid = sorted(svc.tickets)[n % len(svc.tickets)]
            svc.cancel(uid)
        svc.step()
    svc.drain()
    assert not svc.tickets
    assert all(t.finish_reason is not None for t in tickets)
    assert eng.alloc.pages_in_use == 0
    eng.alloc.check()
    st_ = svc.stats
    assert st_["submitted"] == (st_["completed"] + st_["expired"]
                                + st_["cancelled"] + st_["faults"])
