"""HQP core invariants: sensitivity, pruning surgery, Algorithm 1 semantics,
calibration, and quantization — on both the CNN and LM tracks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.configs import get_cnn_config
from repro.core import calibration as calib
from repro.core import pipeline as pipe
from repro.core import pruning as pr
from repro.core import quantization as q
from repro.core import sensitivity as sens
from repro.models import cnn, lm


# ------------------------------------------------------------------ helpers
def small_cnn(arch="resnet18"):
    cfg = dataclasses.replace(get_cnn_config(arch), width_mult=0.25)
    variables = cnn.cnn_init(jax.random.PRNGKey(0), cfg)
    return cfg, variables


def fake_fisher(variables):
    """Deterministic pseudo-Fisher: |w| as the squared-grad stand-in."""
    return jax.tree.map(lambda t: jnp.abs(t.astype(jnp.float32)), variables)


# ------------------------------------------------------------------ masking
def test_cnn_mask_zeroes_exactly_selected_channels():
    cfg, variables = small_cnn()
    specs = sens.cnn_prune_groups(cfg, variables)
    sp = specs[0]
    drop = np.zeros(sp.size, bool)
    drop[[0, 3]] = True
    masked = sens.mask_group(variables, sp, jnp.asarray(drop))
    w = np.asarray(sens._get(masked, sp.members_all[0][0]))
    assert np.all(w[..., 0] == 0) and np.all(w[..., 3] == 0)
    assert np.any(w[..., 1] != 0)


def test_cnn_mask_equals_compact_outputs():
    """Masked model and physically compacted model compute identical logits."""
    cfg, variables = small_cnn("resnet18")
    specs = sens.cnn_prune_groups(cfg, variables)
    fisher = fake_fisher(variables)
    ranked = pr.rank_units(specs, fisher)
    n = ranked.total // 4
    masked = pr.apply_prune_masks(variables, ranked, n)
    compact = pr.compact_params(variables, ranked, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    lm_, _ = cnn.cnn_apply(cfg, masked, x, train=False)
    lc, _ = cnn.cnn_apply(cfg, compact, x, train=False)
    np.testing.assert_allclose(np.asarray(lm_), np.asarray(lc),
                               rtol=1e-4, atol=1e-4)
    assert pr.param_count(compact["params"]) < pr.param_count(
        variables["params"])


def test_mobilenet_mask_equals_compact():
    cfg, variables = small_cnn("mobilenetv3s")
    specs = sens.cnn_prune_groups(cfg, variables)
    # protect_frac keeps every family non-empty (a fully-emptied depthwise
    # block has no valid compact form; the conditional loop would reject it
    # on accuracy long before, but the surgery test must not rely on that)
    ranked = pr.rank_units(specs, fake_fisher(variables), protect_frac=0.25)
    n = ranked.total // 5
    masked = pr.apply_prune_masks(variables, ranked, n)
    compact = pr.compact_params(variables, ranked, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    a, _ = cnn.cnn_apply(cfg, masked, x, train=False)
    b, _ = cnn.cnn_apply(cfg, compact, x, train=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "phi3.5-moe-42b-a6.6b",
                                  "jamba-1.5-large-398b", "xlstm-1.3b"])
def test_lm_mask_equals_compact(arch):
    """LM structural surgery: masked == compacted forward (all unit kinds)."""
    cfg = configs.get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    specs = sens.lm_prune_groups(cfg)
    assert specs, arch
    fisher = fake_fisher(params)
    ranked = pr.rank_units(specs, fisher, protect_frac=0.25)
    n = max(1, ranked.total // 3)
    masked = pr.apply_prune_masks(params, ranked, n)
    compact = pr.compact_params(masked, ranked, n)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend.kind != "none":
        batch["embeds"] = jnp.zeros((2, cfg.frontend.n_embeds, cfg.d_model),
                                    jnp.bfloat16)
    hm, _ = lm.forward(masked, cfg, batch)
    hc, _ = lm.forward(compact, cfg, batch)
    np.testing.assert_allclose(np.asarray(hm, np.float32),
                               np.asarray(hc, np.float32),
                               rtol=0.05, atol=0.05)
    # stacked families compact to the least-pruned layer's width; at this
    # drop fraction at least one family must physically shrink
    assert pr.param_count(compact) < pr.param_count(params)


def test_expert_mask_makes_expert_unroutable():
    cfg = configs.get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    specs = [s for s in sens.lm_prune_groups(cfg) if s.kind == "expert"]
    sp = specs[0]
    drop = np.zeros(sp.size, bool)
    drop[1] = True
    masked = pr.apply_prune_masks(
        params, pr.RankedUnits([sp], np.array([0]), np.array([1]),
                               np.array([0.0])), 1)
    router_b = np.asarray(sens._get(
        masked, [m for m in sp.members_all if "router" in m[0]][0][0][:-1]
        + ("b",)))
    assert router_b[1] < -1e8


# ------------------------------------------------------------------ ranking
def test_rank_units_ascending_and_global():
    cfg, variables = small_cnn()
    specs = sens.cnn_prune_groups(cfg, variables)
    ranked = pr.rank_units(specs, fake_fisher(variables))
    assert np.all(np.diff(ranked.s_values) >= -1e-9)
    assert ranked.total == sum(s.size for s in specs)


def test_group_sensitivity_identifies_important_channel():
    """A channel with large squared grads must rank above zero-grad ones."""
    cfg, variables = small_cnn()
    specs = sens.cnn_prune_groups(cfg, variables)
    sp = specs[0]
    sq = jax.tree.map(jnp.zeros_like, variables)
    leaf_path, axis, block, off = sp.members_grad[0]
    leaf = sens._get(sq, leaf_path)
    hot = jnp.zeros_like(leaf).at[..., 2].set(100.0)
    sq = sens._set(sq, leaf_path, hot)
    s = np.asarray(sens.group_sensitivity(sq, sp))
    assert s[2] == s.max() and s[2] > 0


# ------------------------------------------------------------------ Algorithm 1
def test_conditional_prune_respects_delta_and_is_maximal():
    """Accuracy model: acc = 1 - 0.0005 * n_dropped. With Δ=1.5% the loop
    must stop at exactly the maximal compliant drop count."""
    cfg, variables = small_cnn()
    specs = sens.cnn_prune_groups(cfg, variables)
    sq = fake_fisher(variables)

    def eval_fn(masked):
        # count zeroed channels across the first member of each family
        n = 0
        for sp in specs:
            path, axis, block, off = sp.members_all[0]
            w = np.asarray(sens._get(masked, path))
            w = np.moveaxis(w, axis, -1)
            n += int(np.sum(np.all(w.reshape(-1, w.shape[-1]) == 0, axis=0)))
        return 1.0 - 0.0005 * n

    hqp = pipe.HQPConfig(delta_ax=0.015, step_frac=0.05, max_steps=100)
    res = pipe.conditional_prune(variables, specs, sq, eval_fn, hqp,
                                 a_baseline=1.0, log=lambda s: None)
    assert res.a_baseline - res.a_final <= 0.015 + 1e-9
    # maximality: one more δ-step would have violated (history shows a REJECT
    # or the ranking was exhausted)
    assert (not res.history[-1].accepted) or res.n_drop == res.ranked.total
    # accepted drops: 15 channels max => with step 5% of total...
    assert res.n_drop > 0


def test_conditional_prune_stops_immediately_if_fragile():
    cfg, variables = small_cnn()
    specs = sens.cnn_prune_groups(cfg, variables)
    res = pipe.conditional_prune(
        variables, specs, fake_fisher(variables),
        eval_fn=lambda m: 0.5,               # any pruning tanks accuracy
        hqp=pipe.HQPConfig(delta_ax=0.015), a_baseline=1.0,
        log=lambda s: None)
    assert res.n_drop == 0 and res.theta == 0.0


# ------------------------------------------------------------------ calibration
def test_kl_threshold_clips_outliers():
    """KL calibration on a gaussian + one huge outlier must clip far below
    absmax (the paper's §II-C range-inflation story)."""
    rng = np.random.RandomState(0)
    x = rng.randn(100_000) * 1.0
    x[0] = 80.0                                # outlier inflates absmax
    ts = calib.TensorStats()
    ts.update_amax(x)
    ts.update_hist(x)
    s_absmax = ts.scale("absmax")
    s_kl = ts.scale("kl")
    assert s_kl < 0.25 * s_absmax
    s_pct = ts.scale("percentile")
    assert s_pct < 0.5 * s_absmax


def test_actq_apply_quantizes():
    a = calib.ActQ(mode="amax")
    x = jnp.asarray(np.linspace(-1, 1, 128, dtype=np.float32))
    a.tap("t", x)
    a.mode = "hist"
    a.tap("t", x)
    a.finalize()
    y = np.asarray(a.tap("t", x))
    assert len(np.unique(y)) <= 255
    np.testing.assert_allclose(y, np.asarray(x), atol=0.02)


# ------------------------------------------------------------------ quantization
def test_quantize_lm_params_roundtrip_and_fraction():
    cfg = configs.get_smoke_config("granite-3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = q.quantize_lm_params(params)
    frac = q.quantized_fraction(qp)
    assert frac > 0.5
    # quantized model still runs and is close
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab_size)
    h0, _ = lm.forward(params, cfg, {"tokens": tokens})
    h1, _ = lm.forward(qp, cfg, {"tokens": tokens})
    rel = (np.abs(np.asarray(h1 - h0, np.float32))
           / (np.abs(np.asarray(h0, np.float32)) + 0.5))
    assert np.median(rel) < 0.15


def test_per_channel_beats_per_tensor_quant_error():
    """The production per-channel choice strictly reduces error vs the
    paper's per-tensor step on outlier-bearing weights."""
    rng = np.random.RandomState(0)
    w = rng.randn(128, 64).astype(np.float32)
    w[:, 0] *= 50                              # one outlier channel
    e_tensor = q.quant_error(jnp.asarray(w), 8, "tensor")
    e_channel = q.quant_error(jnp.asarray(w), 8, "channel")
    assert e_channel < 0.25 * e_tensor


@given(bits=st.sampled_from([4, 6, 8]), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_fake_quant_error_bound(bits, seed):
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (32, 32)))
    fq = np.asarray(q.fake_quant(jnp.asarray(w), bits, "tensor"))
    step = np.abs(w).max() / (2 ** (bits - 1) - 1)
    assert np.all(np.abs(fq - w) <= step / 2 + 1e-6)


# ------------------------------------------------------------------ mixed precision
def test_mixed_precision_assignment():
    from repro.core.mixed_precision import MixedPrecisionPolicy, assign_bits
    s = np.arange(100, dtype=np.float32)
    bits = assign_bits(s, MixedPrecisionPolicy(frac_int4=0.3, frac_bf16=0.1))
    assert (bits[:30] == 4).all() and (bits[-10:] == 16).all()
    assert (bits == 8).sum() == 60
