"""Typed compression layer: QuantizedLinear dispatch, jitted (numpy-free)
PTQ, the shared symmetric-quant helper, artifact save/load round-trips, and
the execution-backend registry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.compress import (QuantizedLinear, compress, fake_quant,
                            quantize_linear, quantize_lm_params,
                            quantized_fraction, symmetric_quantize)
from repro.core.pipeline import HQPConfig
from repro.kernels import backend as kb
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.launch import checkpoint as ckpt
from repro.models import layers as L
from repro.models import lm


# ------------------------------------------------------------------ qtypes
def test_quantize_linear_returns_typed_node():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
    q = quantize_linear({"w": w})
    assert isinstance(q, QuantizedLinear)
    assert q.w_q.dtype == jnp.int8 and q.w_q.shape == (64, 32)
    assert q.scale.shape == (32,) and q.bits == 8
    deq = np.asarray(q.w_q, np.float32) * np.asarray(q.scale)[None, :]
    np.testing.assert_allclose(deq, np.asarray(w), atol=float(q.scale.max()))


def test_quantize_linear_stacked_and_expert_layouts():
    """(L, in, out) and (L, E, in, out): per-out-channel scales per leading
    index (the vmapped path)."""
    for shape, sshape in [((3, 16, 8), (3, 8)), ((2, 4, 16, 8), (2, 4, 8))]:
        w = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        q = quantize_linear({"w": w})
        assert q.w_q.shape == shape and q.scale.shape == sshape
        deq = np.asarray(q.w_q, np.float32) * np.asarray(q.scale)[..., None, :]
        assert np.median(np.abs(deq - np.asarray(w))) < 0.02


def test_dense_dispatches_on_type():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (128, 64), jnp.float32) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 128), jnp.bfloat16)
    y_fp = L.dense(x, {"w": w})
    y_q = L.dense(x, quantize_linear({"w": w}))
    rel = (np.abs(np.asarray(y_q - y_fp, np.float32))
           / (np.abs(np.asarray(y_fp, np.float32)) + 0.1))
    assert np.median(rel) < 0.1
    assert L.out_features(quantize_linear({"w": w})) == 64
    assert L.dense_param_bytes(quantize_linear({"w": w})) == 128 * 64 + 64 * 4


def test_quantized_linear_vmaps():
    wq = QuantizedLinear(
        w_q=jnp.ones((4, 16, 8), jnp.int8),
        scale=jnp.full((4, 8), 0.5, jnp.float32), bits=8)
    x = jnp.ones((4, 2, 16), jnp.bfloat16)
    y = jax.vmap(lambda xe, pe: L.dense(xe, pe))(x, wq)
    assert y.shape == (4, 2, 8)
    np.testing.assert_allclose(np.asarray(y, np.float32), 8.0, rtol=1e-2)


# ------------------------------------------------------------------ PTQ
def test_ptq_is_numpy_free_on_lm_track():
    """The LM quantize step must be fully traceable: any host transfer
    (np.asarray on a tracer) raises under jit/eval_shape."""
    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    qp = jax.jit(quantize_lm_params)(params)        # would raise on transfer
    assert quantized_fraction(qp) > 0.5
    abstract = jax.eval_shape(quantize_lm_params, params)
    flat = [l for l in jax.tree.leaves(abstract)
            if getattr(l, "dtype", None) == jnp.int8]
    assert flat, "eval_shape produced no int8 leaves"


def test_shared_helper_single_epsilon():
    """Both tracks share symmetric_quantize: an all-zero tensor quantizes to
    all-zero q with the same finite scale on either path."""
    z = jnp.zeros((8, 8), jnp.float32)
    q, scale = symmetric_quantize(z, 8, axes=(0,))
    assert float(jnp.max(jnp.abs(q))) == 0.0
    assert np.all(np.isfinite(np.asarray(scale)))
    ql = quantize_linear({"w": z})
    np.testing.assert_allclose(np.asarray(ql.scale), np.asarray(scale[0]))
    fq = fake_quant(z, 8, "channel")
    assert float(jnp.max(jnp.abs(fq))) == 0.0


# ------------------------------------------------------------------ compress()
def _tiny_lm_artifact(arch="qwen3-0.6b", prune=False):
    cfg = configs.get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = {}
    if prune:
        kw["sq_grads"] = jax.tree.map(
            lambda t: jnp.abs(t.astype(jnp.float32)), params)
        kw["eval_fn"] = lambda p: 1.0
        kw["hqp"] = HQPConfig(weight_granularity="channel", step_frac=0.1,
                              max_steps=2)
    return cfg, params, compress(params, cfg, log=lambda s: None, **kw)


def test_compress_ptq_only_manifest():
    cfg, params, art = _tiny_lm_artifact()
    m = art.manifest
    assert m.bytes_after < m.bytes_before
    assert 0.5 < m.quantized_fraction <= 1.0
    assert m.theta == 0.0 and not m.pruned and m.track == "int8"
    assert "MB" in m.summary()


def test_compress_prune_then_quantize():
    cfg, params, art = _tiny_lm_artifact(prune=True)
    m = art.manifest
    assert m.pruned and m.theta > 0.0 and m.n_drop > 0
    assert any(v > 0 for v in m.theta_by_family.values())
    assert m.history and m.history[0]["accepted"] in (True, False)
    # the compacted+quantized artifact still runs a forward pass
    tokens = jnp.zeros((1, 8), jnp.int32)
    h, _ = lm.forward(art.params, cfg, {"tokens": tokens})
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


def test_compressed_artifact_serves_decode():
    """Pruned+quantized artifact drives prefill+decode with caches sized
    from the compacted params."""
    cfg, params, art = _tiny_lm_artifact(prune=True)
    from repro.sharding.ctx import default_ctx
    ctx = dataclasses.replace(default_ctx(), quantized_kv=True)
    state = lm.init_decode_state(cfg, 2, 32, ctx, params=art.params)
    prompts = jnp.zeros((2, 4), jnp.int32)
    logits, state = lm.decode_step(art.params, cfg, state, prompts, ctx)
    logits, state = lm.decode_step(art.params, cfg, state,
                                   jnp.zeros((2, 1), jnp.int32), ctx)
    assert logits.shape[0] == 2
    assert bool(jnp.all(jnp.isfinite(logits)))


# ------------------------------------------------------------------ artifact io
def test_artifact_save_load_roundtrip(tmp_path):
    cfg, params, art = _tiny_lm_artifact(prune=True)
    d = str(tmp_path / "artifact")
    ckpt.save_artifact(d, art)
    loaded = ckpt.load_artifact(d)
    assert loaded.manifest.asdict() == art.manifest.asdict()
    la, lb = jax.tree.leaves(art.params), jax.tree.leaves(loaded.params)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structure survives: same treedef, QuantizedLinear nodes intact
    assert (jax.tree.structure(art.params)
            == jax.tree.structure(loaded.params))


def test_artifact_load_rejects_torn_write(tmp_path):
    cfg, params, art = _tiny_lm_artifact()
    d = str(tmp_path / "artifact")
    ckpt.save_artifact(d, art)
    (tmp_path / "artifact" / ckpt.COMMIT_MARKER).unlink()
    with pytest.raises(FileNotFoundError):
        ckpt.load_artifact(d)


def test_checkpoint_flatten_handles_typed_nodes(tmp_path):
    """The step-checkpoint path also round-trips QuantizedLinear leaves
    (GetAttrKey path entries)."""
    tree = {"lin": quantize_linear(
        {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8))})}
    ckpt.save(str(tmp_path), 1, tree)
    restored, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(tree["lin"].w_q),
                                  np.asarray(restored["lin"].w_q))


# ------------------------------------------------------------------ backends
def test_backend_registry_selection():
    assert set(kb.available()) >= {"pallas", "xla", "ref"}
    assert kb.get_backend().name in kb.available()
    with pytest.raises(KeyError):
        kb.get_backend("cuda")
    prev = kb.set_backend("xla")
    try:
        assert kb.get_backend().name == "xla"
    finally:
        kb.set_backend(prev)


def test_ref_backend_matches_xla_through_model_dense():
    """interpret-mode Pallas through the real dense() path == jnp oracle."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (64, 32), jnp.float32) * 0.1
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 64), jnp.bfloat16)
    ql = quantize_linear({"w": w})
    prev = kb.set_backend("xla")
    try:
        y_xla = L.dense(x, ql)
        kb.set_backend("ref")
        y_ref = L.dense(x, ql)
    finally:
        kb.set_backend(prev)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_xla, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ops_int8_matmul_precomputed_scales():
    """Static (calibrated) activation scales pass straight through ops."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (2, 8, 64), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 16), jnp.float32)
    w_q, w_s = ref.quantize_ref(w, axis=0)
    x_q, x_s = ref.quantize_ref(x, axis=-1)
    out = kops.int8_matmul(x_q, w_q, w_s, x_scale=x_s)
    expected = kops.int8_matmul(x, w_q, w_s)
    assert out.shape == (2, 8, 16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=5e-2, atol=5e-2)
