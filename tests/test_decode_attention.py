"""Length-aware decode/prefill attention: the windowed paths must be
BIT-IDENTICAL to the full-mask einsum (out-of-window positions contribute
exact zeros), and the ``decode_attention`` backend primitive must agree with
that oracle on every registered backend — bitwise on ``xla`` (it is the same
einsum), within f32 tolerance on ``ref`` (the Pallas split-KV kernel in
interpret mode, online softmax). This is the regression suite behind the
engine's token-identity contract under windowing + multi-step decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.backend import available, get_backend, set_backend
from repro.models import attention as A

B, HQ, HKV, HD = 3, 8, 4, 32
BLOCK = 16


def _cache(key, max_seq, quantized):
    ks = jax.random.split(key, 4)
    if quantized:
        return {
            "k_q": jax.random.randint(ks[0], (B, max_seq, HKV, HD),
                                      -127, 128, jnp.int8),
            "v_q": jax.random.randint(ks[1], (B, max_seq, HKV, HD),
                                      -127, 128, jnp.int8),
            "k_s": jax.random.uniform(ks[2], (B, max_seq, HKV),
                                      jnp.float32, 0.01, 0.1),
            "v_s": jax.random.uniform(ks[3], (B, max_seq, HKV),
                                      jnp.float32, 0.01, 0.1),
        }
    return {"k": jax.random.normal(ks[0], (B, max_seq, HKV, HD),
                                   jnp.bfloat16),
            "v": jax.random.normal(ks[1], (B, max_seq, HKV, HD),
                                   jnp.bfloat16)}


def _starts(per_slot, sq, max_seq):
    """Per-slot positions spread across the cache (or one scalar); the
    deepest slot pins the window to a non-trivial fraction of max_seq."""
    hi = max_seq // 2 - sq
    if per_slot:
        return jnp.asarray([1, hi // 2, hi], jnp.int32)
    return jnp.int32(hi)


def _window(start, sq, max_seq):
    needed = int(jnp.max(jnp.asarray(start))) + sq
    return min(max_seq, -(-needed // BLOCK) * BLOCK)


@pytest.mark.parametrize("max_seq", [32, 64, 160])
@pytest.mark.parametrize("sq", [1, 5])
@pytest.mark.parametrize("per_slot", [False, True])
@pytest.mark.parametrize("quantized", [False, True])
def test_windowed_cached_attention_bit_identical(quantized, per_slot, sq,
                                                 max_seq):
    """cached_attention(window=W) == cached_attention(window=None) bitwise,
    for W >= start+Sq: the length-aware slice may not change one ulp."""
    key = jax.random.PRNGKey(max_seq * 7 + sq)
    cache = _cache(key, max_seq, quantized)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, sq, HQ, HD),
                          jnp.bfloat16)
    start = _starts(per_slot, sq, max_seq)
    full = A.cached_attention(q, cache, start)
    win = _window(start, sq, max_seq)
    assert win < max_seq or max_seq == 32   # the sweep must actually slice
    windowed = A.cached_attention(q, cache, start, window=win)
    np.testing.assert_array_equal(np.asarray(full, np.float32),
                                  np.asarray(windowed, np.float32))


@pytest.mark.parametrize("max_seq", [32, 96])
@pytest.mark.parametrize("per_slot", [False, True])
@pytest.mark.parametrize("quantized", [False, True])
def test_decode_attention_xla_bitwise_vs_einsum(quantized, per_slot, max_seq):
    """The xla backend's decode primitive is literally the Sq=1 slice of the
    prefill einsum — bitwise, windowed or not. Token identity between the
    engine (decode primitive) and serial decode hinges on this."""
    key = jax.random.PRNGKey(max_seq)
    cache = _cache(key, max_seq, quantized)
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, HQ, HD),
                          jnp.bfloat16)
    start = _starts(per_slot, 1, max_seq)
    oracle = A.cached_attention(q, cache, start)
    prev = set_backend("xla")
    try:
        for win in (None, _window(start, 1, max_seq)):
            out = ops.decode_attention(q, cache, start, window=win)
            np.testing.assert_array_equal(np.asarray(oracle, np.float32),
                                          np.asarray(out, np.float32))
    finally:
        set_backend(prev)


@pytest.mark.parametrize("bk", [16, 64])
@pytest.mark.parametrize("per_slot", [False, True])
@pytest.mark.parametrize("quantized", [False, True])
def test_decode_attention_ref_kernel_vs_einsum(quantized, per_slot, bk):
    """Pallas split-KV kernel (interpret mode) vs the einsum oracle, f32
    tolerance: exercises the per-slot block skip (slots at different depths),
    the KV-tail padding mask, and the fused INT8 dequant epilogue."""
    from repro.kernels.decode_attention import decode_attention_pallas
    max_seq = 80                       # not a multiple of 64: padded tail
    key = jax.random.PRNGKey(bk)
    cache = _cache(key, max_seq, quantized)
    q = jax.random.normal(jax.random.fold_in(key, 3), (B, 1, HQ, HD),
                          jnp.bfloat16)
    start = jnp.broadcast_to(_starts(per_slot, 1, max_seq), (B,))
    oracle = A.cached_attention(q, cache, start)
    if quantized:
        args = (cache["k_q"], cache["v_q"], cache["k_s"], cache["v_s"])
    else:
        args = (cache["k"], cache["v"], None, None)
    out = decode_attention_pallas(q[:, 0], *args, start, bk=bk,
                                  interpret=True)
    # int8 path: the oracle rounds probabilities AND dequantized V to bf16
    # before its dot while the kernel accumulates f32 — values span ~±12
    # (127 * 0.1 scale), so bf16 rounding alone is ~0.05 absolute
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle[:, 0], np.float32),
                               rtol=3e-2, atol=1e-1 if quantized else 3e-2)


def test_one_token_prefill_chunk_stays_on_prefill_route():
    """A 1-token cache-continuation prefill chunk is shape-identical to a
    decode step, but the STATIC ``route="prefill"`` must keep it on the
    ``prefill_attention`` primitive: on the ref/pallas backends the decode
    kernel is only tolerance-equal to the prefill kernel, and a tail chunk
    through it would break the engine's bit-level token-identity contract
    vs serial whole-prompt prefill. Asserted bitwise on ``ref`` — the
    prefill kernel's absolute causal limits make chunked == whole-prompt
    bit-for-bit even through the Pallas online softmax."""
    from repro import configs
    from repro.models import lm
    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 9), 0,
                              cfg.vocab_size)
    prev = set_backend("ref")       # backend whose decode kernel != prefill
    try:
        state = lm.init_decode_state(cfg, 1, 32)
        full, _ = lm.decode_step(params, cfg, state, toks)
        state2 = lm.init_decode_state(cfg, 1, 32)
        _, state2 = lm.decode_step(params, cfg, state2, toks[:, :8],
                                   route="prefill")
        last, _ = lm.decode_step(params, cfg, state2, toks[:, 8:],
                                 route="prefill")   # the 1-token tail chunk
        np.testing.assert_array_equal(np.asarray(full[:, -1], np.float32),
                                      np.asarray(last[:, 0], np.float32))
    finally:
        set_backend(prev)


def test_decode_attention_registered_on_all_backends():
    """Every registered backend exposes the decode primitive; every backend
    that can execute on this platform (compiled `pallas` needs a real TPU;
    `ref` runs the same kernel interpreted anywhere) produces a finite,
    well-shaped result agreeing with `xla` within f32 tolerance."""
    assert set(available()) == {"pallas", "xla", "ref"}
    for name in available():
        assert callable(get_backend(name).decode_attention)
    key = jax.random.PRNGKey(9)
    cache = _cache(key, 32, False)
    q = jax.random.normal(key, (B, 1, HQ, HD), jnp.bfloat16)
    start = jnp.asarray([0, 5, 31], jnp.int32)
    # the compiled (non-interpret) pallas kernel only lowers on real TPU
    run = ["xla", "ref"] + (["pallas"] if jax.default_backend() == "tpu"
                            else [])
    outs = {}
    for name in run:
        prev = set_backend(name)
        try:
            outs[name] = np.asarray(
                ops.decode_attention(q, cache, start), np.float32)
        finally:
            set_backend(prev)
        assert outs[name].shape == (B, 1, HQ, HD)
        assert np.all(np.isfinite(outs[name]))
        np.testing.assert_allclose(outs[name], outs["xla"],
                                   rtol=3e-2, atol=3e-2)
