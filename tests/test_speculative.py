"""Self-speculative serving: the HQP artifact drafts, bf16 verifies.

Load-bearing guarantees:
  * GREEDY speculative engine output is TOKEN-IDENTICAL to serial bf16
    decode — the drafter can only ever propose, never change a token
    (prompt lengths x spec-K x cycles x KV dtype, incl. EOS/budget stops
    landing mid-cycle and the cache-capacity k_eff cap);
  * sampling is seed-deterministic: same seed => same tokens, engine vs
    serial (plain mode) and run vs run (speculative mode);
  * ``Engine.stats`` alone suffice to compute acceptance rate, in both
    plain and speculative modes (drafted/accepted token counters);
  * the artifact manifest records drafter compatibility (vocab/arch hash)
    and ``SpecDecoder`` refuses mismatched or recurrent-state drafters;
  * ``scripts/check_bench.py`` fails by NAME on a missing expected variant
    and gates the speculative acceptance-rate floor.
"""
import dataclasses
import importlib.util
import json
import pathlib

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # bare container: skip property tests
    from _hypothesis_stub import given, settings, st

from repro import configs
from repro.compress import arch_fingerprint, compress
from repro.models import lm
from repro.serving import (Engine, Request, SamplingConfig, SchedulerConfig,
                           SpecDecoder, check_drafter_compat, serial_decode)
from repro.sharding.ctx import default_ctx

ARCH = "qwen3-0.6b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, log=lambda s: None)   # PTQ-only artifact
    return cfg, params, art


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).tolist() for n in lens]


def _spec_engine(cfg, params, art, *, quantized_kv=True, k=4, cycles=1,
                 n_slots=3, max_seq=64, chunk=5, sampling=None):
    ctx_q = dataclasses.replace(default_ctx(), quantized_kv=quantized_kv)
    return Engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                  sched=SchedulerConfig(prefill_chunk=chunk),
                  sampling=sampling, draft_params=art.params, spec_k=k,
                  spec_cycles=cycles, draft_ctx=ctx_q,
                  draft_manifest=art.manifest)


# ----------------------------------------------------------- greedy identity
@pytest.mark.parametrize("quantized_kv", [True, False])
def test_spec_greedy_token_identical(setup, quantized_kv):
    """Staggered requests through the speculative engine == serial bf16
    greedy decode, token for token — the drafter (INT8 weights, either KV
    dtype) only ever proposes."""
    cfg, params, art = setup
    prompts = _prompts(cfg, [9, 13, 5], seed=2)
    eng = _spec_engine(cfg, params, art, quantized_kv=quantized_kv,
                       k=4, cycles=2)
    res = eng.run([Request(prompt=p, max_new_tokens=8) for p in prompts],
                  arrival_ticks=[0, 2, 4])
    for i, p in enumerate(prompts):
        ref = serial_decode(params, cfg, p, 8, max_seq=64)
        assert res[i].tokens == ref, (i, res[i].tokens, ref)
    assert eng.stats["drafted_tokens"] > 0
    assert 0 < eng.stats["accepted_tokens"] <= eng.stats["drafted_tokens"]


def test_spec_eos_mid_cycle(setup):
    """An EOS landing inside an accepted draft run must truncate the
    emission, roll the caches back, and finish the request — identically
    to serial decode with the same EOS id."""
    cfg, params, art = setup
    prompt = _prompts(cfg, [9], seed=3)[0]
    eos_tok = serial_decode(params, cfg, prompt, 5, max_seq=64)[2]
    eng = _spec_engine(cfg, params, art, k=4, cycles=2, n_slots=1)
    res = eng.run([Request(prompt=prompt, max_new_tokens=10,
                           eos_id=eos_tok)])
    ref = serial_decode(params, cfg, prompt, 10, max_seq=64, eos_id=eos_tok)
    assert res[0].tokens == ref
    assert res[0].finish_reason == "eos"


def test_spec_cache_capacity_caps_draft_length(setup):
    """A slot near the cache end must shrink k_eff (the verify chunk's
    writes CLAMP out of range, silently corrupting history) — output stays
    identical with a prompt that leaves less than spec_k+1 headroom."""
    cfg, params, art = setup
    prompt = _prompts(cfg, [24], seed=4)[0]
    eng = _spec_engine(cfg, params, art, k=8, cycles=2, n_slots=1,
                       max_seq=32, chunk=8)
    res = eng.run([Request(prompt=prompt, max_new_tokens=7)])
    assert res[0].tokens == serial_decode(params, cfg, prompt, 7, max_seq=32)


@given(lens=st.lists(st.integers(1, 24), min_size=1, max_size=2),
       k=st.integers(1, 6), cycles=st.integers(1, 3),
       quantized=st.booleans())
@settings(max_examples=5, deadline=None)
def test_spec_greedy_identity_property(lens, k, cycles, quantized):
    """Property sweep: ANY prompt lengths x spec-K x cycle count x KV dtype
    keep speculative greedy output == serial bf16 greedy decode."""
    cfg = configs.get_smoke_config(ARCH)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, log=lambda s: None)
    prompts = _prompts(cfg, lens, seed=sum(lens) + k + cycles)
    eng = _spec_engine(cfg, params, art, quantized_kv=quantized, k=k,
                       cycles=cycles, n_slots=2)
    res = eng.run([Request(prompt=p, max_new_tokens=6) for p in prompts],
                  arrival_ticks=[2 * i for i in range(len(prompts))])
    for i, p in enumerate(prompts):
        ref = serial_decode(params, cfg, p, 6, max_seq=64)
        assert res[i].tokens == ref, (lens, k, cycles, quantized,
                                      res[i].tokens, ref)


# ----------------------------------------------------------------- sampling
def test_sampling_determinism_engine_vs_serial(setup):
    """Fixed seed => the engine's batched sampled decode equals serial
    sampled decode token-for-token (the shared seed x position key rule),
    and genuinely differs from greedy."""
    cfg, params, _ = setup
    scfg = SamplingConfig(temperature=0.8, top_k=8, seed=7)
    prompts = _prompts(cfg, [9, 13], seed=5)
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=5), sampling=scfg)
    res = eng.run([Request(prompt=p, max_new_tokens=8) for p in prompts])
    diff_from_greedy = False
    for i, p in enumerate(prompts):
        ref = serial_decode(params, cfg, p, 8, max_seq=64, sampling=scfg)
        assert res[i].tokens == ref, (i, res[i].tokens, ref)
        diff_from_greedy |= (res[i].tokens
                            != serial_decode(params, cfg, p, 8, max_seq=64))
    assert diff_from_greedy, "temperature sampling never left the argmax"


def test_spec_sampling_fixed_seed_deterministic(setup):
    """Speculative sampling (rejection-sampled) is run-to-run deterministic
    for a fixed seed, and temperature=0 sampling collapses to the greedy
    (serial-identical) path."""
    cfg, params, art = setup
    prompts = _prompts(cfg, [9, 13], seed=6)
    scfg = SamplingConfig(temperature=0.8, top_k=8, seed=11)
    outs = []
    for _ in range(2):
        eng = _spec_engine(cfg, params, art, k=4, cycles=2, n_slots=2,
                           sampling=scfg)
        res = eng.run([Request(prompt=p, max_new_tokens=6) for p in prompts])
        outs.append({i: r.tokens for i, r in res.items()})
    assert outs[0] == outs[1]
    eng = _spec_engine(cfg, params, art, k=4, cycles=2, n_slots=2,
                       sampling=SamplingConfig(temperature=0.0, seed=11))
    res = eng.run([Request(prompt=p, max_new_tokens=6) for p in prompts])
    for i, p in enumerate(prompts):
        assert res[i].tokens == serial_decode(params, cfg, p, 6, max_seq=64)


# -------------------------------------------------------------------- stats
def test_stats_acceptance_computable_plain_mode(setup):
    """Plain multi-step decode: drafted_tokens counts EVERY device
    candidate for slots live at dispatch (mid-scan freezes included — the
    device work the old stats under-counted), accepted_tokens the ones
    that landed, so acceptance rate falls out of stats alone."""
    cfg, params, _ = setup
    prompts = _prompts(cfg, [9, 5], seed=7)
    # request 0 stops via EOS partway through a decode_steps=8 scan
    eos_tok = serial_decode(params, cfg, prompts[0], 3, max_seq=64)[2]
    eng = Engine(params, cfg, n_slots=2, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=4, decode_steps=8))
    res = eng.run([Request(prompt=prompts[0], max_new_tokens=6,
                           eos_id=eos_tok),
                   Request(prompt=prompts[1], max_new_tokens=6)])
    emitted_decode = sum(len(r.tokens) for r in res.values()) - 2  # prefill
    assert eng.stats["accepted_tokens"] == emitted_decode
    # the EOS'd slot burned full scans while frozen: strictly more drafted
    assert eng.stats["drafted_tokens"] > eng.stats["accepted_tokens"]
    rate = eng.stats["accepted_tokens"] / eng.stats["drafted_tokens"]
    assert 0 < rate < 1


def test_stats_acceptance_computable_spec_mode(setup):
    """Speculative stats: acceptance = accepted/drafted from stats alone;
    corrections are emitted but never counted as accepted drafts."""
    cfg, params, art = setup
    prompts = _prompts(cfg, [9, 13], seed=8)
    eng = _spec_engine(cfg, params, art, k=4, cycles=2, n_slots=2)
    res = eng.run([Request(prompt=p, max_new_tokens=8) for p in prompts])
    emitted_decode = sum(len(r.tokens) for r in res.values()) - 2
    assert eng.stats["accepted_tokens"] <= eng.stats["drafted_tokens"]
    # every decode-emitted token is an accepted draft or a correction;
    # corrections = emitted - accepted >= number of decode dispatches' 1
    assert eng.stats["accepted_tokens"] < emitted_decode
    rate = eng.stats["accepted_tokens"] / eng.stats["drafted_tokens"]
    assert 0 < rate <= 1


# ----------------------------------------------------- manifest / construction
def test_manifest_records_drafter_compat(setup):
    cfg, _, art = setup
    assert art.manifest.vocab_size == cfg.vocab_size
    assert art.manifest.arch_hash == arch_fingerprint(cfg)
    check_drafter_compat(cfg, art.manifest)      # must not raise

    bad = dataclasses.replace(art.manifest, arch_hash="deadbeef00000000")
    with pytest.raises(ValueError, match="arch_hash"):
        check_drafter_compat(cfg, bad)
    bad2 = dataclasses.replace(art.manifest,
                               vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab_size"):
        check_drafter_compat(cfg, bad2)
    # pre-speculative artifacts (no recorded hash) still load
    legacy = dataclasses.replace(art.manifest, arch_hash=None,
                                 vocab_size=None)
    check_drafter_compat(cfg, legacy)


def test_spec_rejects_recurrent_patterns():
    """Rollback-by-pos only exists for KV caches: recurrent (xLSTM/Mamba)
    patterns must be refused at construction, before any device work."""
    cfg = configs.get_smoke_config("xlstm-1.3b")
    with pytest.raises(NotImplementedError, match="rewind"):
        SpecDecoder(cfg, draft_params=None, verify_params=None)


# -------------------------------------------------------------- check_bench
def _load_check_bench():
    path = (pathlib.Path(__file__).resolve().parents[1] / "scripts"
            / "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench_doc(tmp_path, serving):
    doc = {"schema": "repro-bench/v1",
           "rows": [{"name": "serving/x", "us_per_call": 1.0,
                     "derived": "ok"}],
           "errors": [], "serving": serving}
    p = tmp_path / "BENCH_pr.json"
    p.write_text(json.dumps(doc))
    return p


def _variant(**kw):
    v = {"n_requests": 3, "tokens_per_s": 100.0, "latency_p50_ms": 1.0,
         "latency_p95_ms": 2.0, "ttft_p50_ms": 1.0, "ttft_p95_ms": 2.0,
         "param_bytes": 10, "out_tokens": 30}
    v.update(kw)
    return v


def test_check_bench_names_missing_variant(tmp_path, capsys):
    cb = _load_check_bench()
    path = _bench_doc(tmp_path, {
        "schema": "repro-bench-serving/v1",
        "expected_variants": ["bf16", "speculative"],
        "variants": {"bf16": _variant()}})
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    out = capsys.readouterr().out
    assert "missing expected variant 'speculative'" in out


def test_check_bench_gates_acceptance_floor(tmp_path, capsys):
    cb = _load_check_bench()
    spec = _variant(acceptance_rate=0.5, drafted_tokens=100,
                    accepted_tokens=50, baseline_tokens_per_s=50.0)
    path = _bench_doc(tmp_path, {
        "schema": "repro-bench-serving/v1",
        "expected_variants": ["speculative"],
        "variants": {"speculative": spec}})
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    out = capsys.readouterr().out
    assert "acceptance rate" in out and "0.7" in out


def test_check_bench_gates_speculative_speedup(tmp_path, capsys):
    cb = _load_check_bench()
    spec = _variant(acceptance_rate=0.9, drafted_tokens=100,
                    accepted_tokens=90, baseline_tokens_per_s=50.0,
                    tokens_per_s=40.0)
    path = _bench_doc(tmp_path, {
        "schema": "repro-bench-serving/v1",
        "expected_variants": ["speculative"],
        "variants": {"speculative": spec,
                     "spec_baseline": _variant(tokens_per_s=50.0)}})
    with pytest.raises(SystemExit):
        cb.main([str(path)])
    assert "does not beat" in capsys.readouterr().out


def test_check_bench_accepts_healthy_speculative(tmp_path):
    cb = _load_check_bench()
    spec = _variant(acceptance_rate=0.85, drafted_tokens=100,
                    accepted_tokens=85, baseline_tokens_per_s=50.0,
                    tokens_per_s=80.0)
    path = _bench_doc(tmp_path, {
        "schema": "repro-bench-serving/v1",
        "expected_variants": ["speculative", "spec_baseline"],
        "variants": {"speculative": spec,
                     "spec_baseline": _variant(tokens_per_s=50.0)}})
    assert cb.main([str(path)]) == 0
