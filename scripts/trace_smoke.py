"""CI trace-smoke: run the engine under ``--trace-dir`` and assert the
exported Chrome trace is well-formed AND internally consistent.

  PYTHONPATH=src python scripts/trace_smoke.py

What it proves (the §16 observability contract, over a real subprocess):

  * ``serve --smoke --engine --trace-dir D`` exits 0 and writes
    ``D/trace.json`` + ``D/spans.jsonl``;
  * trace.json is a well-formed Chrome trace-event file (traceEvents
    list; every X event has ts and dur >= 0; every i event has ts) that
    Perfetto / chrome://tracing will load;
  * every submitted uid reaches exactly one terminal reason;
  * per uid, queued + active tile the request envelope: their summed
    duration matches the request span within 5% (the acceptance bound);
  * per request track, queued/active spans never overlap;
  * span token coverage: the prefill/decode/spec spans recorded for a
    uid account for every token the finish instant reports — their
    summed ``tokens`` args equal both the recorder's accumulated
    ``span_tokens`` and the engine's ``n_tokens``.
"""
from __future__ import annotations

import json
import pathlib
import signal
import subprocess
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
from repro.telemetry import schema  # noqa: E402

# an external timeout kill must raise through subprocess.run so it reaps
# the serve child — a leaked server steals CPU from every later bench
signal.signal(signal.SIGTERM, lambda *_a: sys.exit(143))

TOKENS = 8
COVERAGE_TOL = 0.05   # queued+active vs request envelope (acceptance bound)
RUN_TIMEOUT_S = 540


def fail(msg: str) -> None:
    print(f"trace_smoke: FAIL: {msg}")
    raise SystemExit(1)


def check_chrome_shape(trace: dict) -> list:
    """Well-formedness: the invariants Perfetto's JSON importer needs."""
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"traceEvents is {type(events).__name__}, want non-empty list")
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            fail(f"unexpected event phase {ph!r}: {ev}")
        if ph == "X":
            if not isinstance(ev.get("ts"), (int, float)):
                fail(f"X event without numeric ts: {ev}")
            if not (isinstance(ev.get("dur"), (int, float))
                    and ev["dur"] >= 0):
                fail(f"X event with bad dur: {ev}")
        if ph == "i" and not isinstance(ev.get("ts"), (int, float)):
            fail(f"i event without numeric ts: {ev}")
        if ph != "M" and ev.get("name") not in (
                schema.SPAN_NAMES + schema.INSTANT_NAMES + ("step",)):
            fail(f"undeclared event name {ev.get('name')!r} "
                 f"(schema.SPAN_NAMES/INSTANT_NAMES): {ev}")
    return events


def check_lifecycle(records: list) -> dict:
    """Exactly one terminal per uid; spans tile and never overlap;
    span tokens account for the tokens the finish instant reports.
    Returns per-uid summary for the final print."""
    by_uid: dict = {}
    for rec in records:
        uid = rec.get("uid")
        if uid is None:
            continue
        by_uid.setdefault(uid, []).append(rec)
    if not by_uid:
        fail("no per-request records in spans.jsonl")

    for uid, recs in sorted(by_uid.items()):
        finals = [r for r in recs if r["type"] == "instant"
                  and r["name"] == "finish"]
        if len(finals) != 1:
            fail(f"uid {uid}: {len(finals)} terminal instants, want "
                 f"exactly 1 ({[f['args'] for f in finals]})")
        fin = finals[0]
        if fin["args"].get("reason") not in schema.TERMINAL_REASONS:
            fail(f"uid {uid}: terminal reason {fin['args']!r} not in "
                 f"schema.TERMINAL_REASONS")
        spans = {n: [r for r in recs if r["type"] == "span"
                     and r["name"] == n] for n in schema.SPAN_NAMES}
        if len(spans["request"]) != 1:
            fail(f"uid {uid}: {len(spans['request'])} request envelopes")
        req = spans["request"][0]
        req_dur = req["t1"] - req["t0"]

        # queued + active tile the envelope within the acceptance bound
        parts = spans["queued"] + spans["active"]
        part_dur = sum(r["t1"] - r["t0"] for r in parts)
        if req_dur > 0 and abs(part_dur - req_dur) > COVERAGE_TOL * req_dur:
            fail(f"uid {uid}: queued+active cover {part_dur:.6f}s of a "
                 f"{req_dur:.6f}s request envelope "
                 f"(off by {abs(part_dur - req_dur) / req_dur:.1%}, "
                 f"tolerance {COVERAGE_TOL:.0%})")
        # ... and never overlap each other on the track
        ordered = sorted(parts, key=lambda r: r["t0"])
        for a, b in zip(ordered, ordered[1:]):
            if b["t0"] < a["t1"] - 1e-9:
                fail(f"uid {uid}: {a['name']} [{a['t0']}, {a['t1']}] "
                     f"overlaps {b['name']} [{b['t0']}, {b['t1']}]")

        # token coverage: work spans account for every reported token
        work = spans["prefill"] + spans["decode"] + spans["spec"]
        span_tok = sum(int(r["args"].get("tokens", 0)) for r in work)
        if span_tok != fin["args"].get("span_tokens"):
            fail(f"uid {uid}: work spans carry {span_tok} tokens but the "
                 f"finish instant recorded span_tokens="
                 f"{fin['args'].get('span_tokens')!r}")
        if span_tok != fin["args"].get("n_tokens"):
            fail(f"uid {uid}: work spans emitted {span_tok} tokens but "
                 f"finish reports n_tokens="
                 f"{fin['args'].get('n_tokens')!r} (every generated token "
                 f"— prefill tail included — belongs to exactly one span)")
        by_uid[uid] = {"reason": fin["args"]["reason"],
                       "n_tokens": fin["args"].get("n_tokens", 0)}
    return by_uid


def main() -> int:
    with tempfile.TemporaryDirectory(prefix="trace_smoke_") as tmp:
        trace_dir = pathlib.Path(tmp) / "trace"
        cmd = [sys.executable, "-u", "-m", "repro.launch.serve",
               "--arch", "qwen3-0.6b", "--smoke", "--engine",
               "--tokens", str(TOKENS), "--trace-dir", str(trace_dir)]
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=RUN_TIMEOUT_S)
        if proc.returncode != 0:
            fail(f"serve exited {proc.returncode}\n--- output ---\n"
                 f"{proc.stdout}\n{proc.stderr}")
        trace_path = trace_dir / "trace.json"
        jsonl_path = trace_dir / "spans.jsonl"
        for p in (trace_path, jsonl_path):
            if not p.is_file():
                fail(f"{p.name} not written under --trace-dir "
                     f"({sorted(x.name for x in trace_dir.glob('*'))})")

        trace = json.loads(trace_path.read_text())
        events = check_chrome_shape(trace)
        records = [json.loads(line)
                   for line in jsonl_path.read_text().splitlines()]
        summary = check_lifecycle(records)

    n_tok = sum(s["n_tokens"] for s in summary.values())
    print(f"trace_smoke: OK ({len(events)} trace events, "
          f"{len(summary)} request(s), {n_tok} tokens; every uid has one "
          f"terminal, queued+active tile request within {COVERAGE_TOL:.0%}, "
          f"work spans account for all tokens)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
