"""Rebuild one dry-run cell, save its HLO, and print the top byte/collective
contributors (loop-aware). The hillclimb profiler.

  PYTHONPATH=src python scripts/diag_cell.py <arch> <shape> [variant]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.sharding as jsh

from repro.launch import dryrun
from repro.roofline import hlo_cost
from repro.roofline.hlo_cost import _shape_bytes


def build(arch, shape_name, variant="baseline"):
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.ctx import make_ctx
    from repro.sharding import rules
    from repro.models import lm
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh()
    quantized_kv = variant.startswith(("hqp", "int8kv"))
    pure_dp = "puredp" in variant and shape.global_batch % 256 == 0
    ctx = make_ctx(mesh, batch_sharded=shape.global_batch >= 16,
                   quantized_kv=quantized_kv, remat=(shape.kind == "train"),
                   pure_dp=pure_dp)
    params_abs = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    if variant.startswith(("hqp", "int8w")):
        params_abs = jax.eval_shape(dryrun.quantize_lm_params_abstract, params_abs)
    p_sh = rules.param_shardings(params_abs, ctx)
    mk = lambda specs: jax.tree.map(
        lambda s: jsh.NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jsh.PartitionSpec))
    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                state_dtype="int8" if cfg.param_count() > 5e10 else "f32")
            opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)
            o_sh = mk(rules.opt_state_specs(params_abs, opt_abs, ctx))
            b_sh = mk(rules.batch_specs(cfg, ctx))
            step = make_train_step(cfg, ctx, opt_cfg)
            ins = dryrun.input_specs(cfg, shape)
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                              donate_argnums=(0, 1)).lower(
                params_abs, opt_abs, ins["batch"])
        else:
            ins = dryrun.input_specs(cfg, shape, quantized_kv)
            s_sh = mk(rules.decode_state_specs(cfg, ins["state"], ctx))
            t_sh = jsh.NamedSharding(mesh, jsh.PartitionSpec(
                ctx.batch_spec()[0], None))

            def step(params, state, tokens):
                return lm.decode_step(params, cfg, state, tokens, ctx)
            lowered = jax.jit(step, in_shardings=(p_sh, s_sh, t_sh),
                              donate_argnums=(1,)).lower(
                params_abs, ins["state"], ins["tokens"])
        return lowered.compile()


def op_bytes(hc, op):
    if op.opcode in hlo_cost._FREE_OPS or op.opcode == "while":
        return 0
    if op.opcode == "fusion":
        m = re.search(r"(?:calls|to_apply)=\{?%?([\w.\-]+)", op.attrs)
        return hc._fusion_bytes(op, m.group(1)) if m else 0
    if op.opcode == "dynamic-update-slice":
        return (2 * _shape_bytes(hc.shape.get(op.operands[1], ""))
                if len(op.operands) > 1 else 0)
    if op.opcode == "dynamic-slice":
        return 2 * _shape_bytes(op.result_text)
    return _shape_bytes(op.result_text) + hc._operand_bytes(op)


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    variant = sys.argv[3] if len(sys.argv) > 3 else "baseline"
    compiled = build(arch, shape_name, variant)
    txt = compiled.as_text()
    tag = f"{arch}_{shape_name}_{variant}".replace("/", "_")
    path = f"/tmp/{tag}.hlo"
    open(path, "w").write(txt)
    print("HLO saved:", path)
    hc = hlo_cost.HloCost(txt)
    rows = []
    colls = []

    def walk(comp_name, mult, prefix):
        comp = hc.comps[comp_name]
        for op in comp.ops:
            if op.opcode == "while":
                body = hc._attr_comp(op, "body")
                walk(body, mult * hc._trip_count(op),
                     prefix + f">{hc._trip_count(op)}x")
            else:
                b = op_bytes(hc, op)
                if b * mult > 1e9:
                    rows.append((b * mult, mult, op.opcode, op.name, prefix,
                                 op.result_text[:60]))
                if any(op.opcode.startswith(c) for c in hlo_cost.COLLECTIVES):
                    cb = _shape_bytes(op.result_text)
                    if cb * mult > 1e8:
                        colls.append((cb * mult, mult, op.opcode,
                                      op.result_text[:70]))
    walk(hc.entry, 1, "")
    res = hc.cost()
    print(f"TOTAL bytes/dev {res.bytes/1e9:.1f}GB  coll/dev "
          f"{res.collective_bytes/1e9:.1f}GB  flops/dev {res.flops:.3e}")
    print("\n--- top HBM-byte ops (xTrips) ---")
    for b, m, oc, n, pre, rt in sorted(rows, reverse=True)[:14]:
        print(f"{b/1e9:9.2f}GB x{m:5d} {oc:22s} {pre:8s} {n[:34]:34s} {rt}")
    print("\n--- top collectives ---")
    for b, m, oc, rt in sorted(colls, reverse=True)[:12]:
        print(f"{b/1e9:9.2f}GB x{m:5d} {oc:20s} {rt}")


if __name__ == "__main__":
    main()
