"""CI chaos-smoke: boot ``serve --http`` with a deliberately small paged
arena, then attack it — malformed HTTP, a slow-loris, mid-stream client
disconnects, page exhaustion, a deadline storm — and finally SIGTERM it
mid-load.

  PYTHONPATH=src python scripts/chaos_smoke.py

What it proves (the fault-tolerance contract, over real sockets against
a real subprocess — in-process scenarios live in tests/ and
bench_chaos):

  * malformed requests (garbage line, bad JSON, non-POST generate,
    bad prompt types, oversized body) each get a clean 4xx, never a
    dropped connection or a pump exception;
  * a slow-loris client is timed out by the event loop (408/close)
    without ever touching the engine thread;
  * clients that vanish mid-stream (RST) have their requests cancelled
    and every page freed — ``pages_in_use`` returns to zero;
  * page exhaustion under concurrent load fault-isolates: every stream
    still terminates with exactly one ``done``/``error`` event, the
    server keeps answering, and no page leaks;
  * a deadline storm is absorbed by shedding (429) / expiry — never a
    5xx or a hang;
  * SIGTERM mid-load drains cleanly: in-flight streams finish, exit 0.
"""
from __future__ import annotations

import json
import re
import signal
import subprocess
import sys
import threading
import time

sys.path.insert(0, "src")

from repro.serving import faults  # noqa: E402

# importing http_smoke also installs its atexit child-reaper + SIGTERM
# handler; registering our server in _children means no fail() path (or
# external timeout kill) can leak it to poison later benches
from http_smoke import _children, http_exchange, parse_sse  # noqa: E402

BOOT_TIMEOUT_S = 420
STREAM_TIMEOUT_S = 120
EXIT_TIMEOUT_S = 60
TOTAL_PAGES = 25        # 4 slots x ceil(128/8)=16 pages would need 65:
                        # deliberately starved so concurrency exhausts it
HOST = "127.0.0.1"


def fail(msg: str, proc=None) -> None:
    print(f"chaos_smoke: FAIL: {msg}")
    if proc is not None:
        proc.kill()
        out = proc.stdout.read() if proc.stdout else ""
        print(f"--- server output ---\n{out}")
    raise SystemExit(1)


def post(port: int, body: dict, timeout_s: float = STREAM_TIMEOUT_S):
    """POST /v1/generate. Returns (head, events) — SSE events for a 200
    stream, [] for an error status (429/503/...: the body is JSON, not
    SSE)."""
    payload = json.dumps(body).encode()
    raw = http_exchange(port, (
        f"POST /v1/generate HTTP/1.1\r\nHost: s\r\n"
        f"Content-Length: {len(payload)}\r\n\r\n").encode() + payload,
        timeout_s)
    head = raw.partition(b"\r\n\r\n")[0].decode("latin-1", "replace")
    if not head.startswith("HTTP/1.1 200"):
        return head, []
    return parse_sse(raw)


def stats(port: int) -> dict:
    raw = http_exchange(port, b"GET /stats HTTP/1.1\r\nHost: s\r\n\r\n", 30)
    return json.loads(raw.partition(b"\r\n\r\n")[2])


def wait_pages_zero(port: int, timeout_s: float = 30.0) -> dict:
    t0 = time.monotonic()
    while True:
        st = stats(port)
        if st["engine"]["pages_in_use"] == 0 and st["slots_active"] == 0:
            return st
        if time.monotonic() - t0 > timeout_s:
            fail(f"pages_in_use={st['engine']['pages_in_use']} "
                 f"slots_active={st['slots_active']} still nonzero after "
                 f"{timeout_s}s: {st}")
        time.sleep(0.2)


def expect_status(got: str, want: str, what: str, proc) -> None:
    if want not in got:
        fail(f"{what}: status {got!r} (want {want})", proc)
    print(f"chaos_smoke: {what} -> {got or '<closed>'}")


def main() -> int:
    cmd = [sys.executable, "-u", "-m", "repro.launch.serve",
           "--arch", "qwen3-0.6b", "--smoke", "--engine", "--http",
           "--port", "0", "--queue-depth", "4", "--page-size", "8",
           "--no-prefix-cache", "--total-pages", str(TOTAL_PAGES),
           "--watchdog-s", "120"]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    _children.append(proc)
    port, t0 = None, time.monotonic()
    for line in proc.stdout:
        print(f"[server] {line.rstrip()}")
        m = re.search(r"listening on http://[\d.]+:(\d+)", line)
        if m:
            port = int(m.group(1))
            break
        if time.monotonic() - t0 > BOOT_TIMEOUT_S:
            fail(f"no listen line within {BOOT_TIMEOUT_S}s", proc)
        if proc.poll() is not None:
            fail(f"server exited {proc.returncode} before listening", proc)
    if port is None:
        fail("server stdout closed before the listen line", proc)
    print(f"chaos_smoke: server up on port {port} "
          f"({time.monotonic() - t0:.0f}s boot)")

    # ---- 0. sanity: one healthy stream (also warms decode for later)
    head, events = post(port, {"prompt_len": 12, "max_new_tokens": 6})
    if not head.startswith("HTTP/1.1 200") or events[-1][0] != "done":
        fail(f"sanity stream broken: {head!r} {events!r}", proc)
    print("chaos_smoke: sanity stream OK")

    # ---- 1. malformed HTTP: every attack gets a clean 4xx
    expect_status(faults.http_malformed(HOST, port, b"garbage\r\n\r\n"),
                  "400", "garbage request line", proc)
    expect_status(faults.http_malformed(
        HOST, port, b"POST /v1/generate HTTP/1.1\r\nHost: s\r\n"
                    b"Content-Length: 7\r\n\r\n{not js"),
        "400", "malformed JSON body", proc)
    expect_status(faults.http_malformed(
        HOST, port, b"GET /v1/generate HTTP/1.1\r\nHost: s\r\n\r\n"),
        "400", "non-POST generate", proc)
    bad = json.dumps({"prompt": "strings are not token ids"}).encode()
    expect_status(faults.http_malformed(
        HOST, port, b"POST /v1/generate HTTP/1.1\r\nHost: s\r\n"
                    b"Content-Length: %d\r\n\r\n%s" % (len(bad), bad)),
        "400", "non-list prompt", proc)
    big = json.dumps({"prompt": [1] * 500, "max_new_tokens": 500}).encode()
    expect_status(faults.http_malformed(
        HOST, port, b"POST /v1/generate HTTP/1.1\r\nHost: s\r\n"
                    b"Content-Length: %d\r\n\r\n%s" % (len(big), big)),
        "400", "overlong prompt+budget", proc)
    expect_status(faults.http_malformed(
        HOST, port, b"POST /v1/generate HTTP/1.1\r\nHost: s\r\n"
                    b"Content-Length: 9999999999\r\n\r\n"),
        "413", "oversized body", proc)

    # ---- 2. slow-loris: request timeout answers 408 (or closes), the
    # pump never sees the connection
    got = faults.http_slow_loris(HOST, port, hold_s=12.0, timeout_s=30.0)
    if got and "408" not in got:
        fail(f"slow-loris got {got!r} (want 408 or close)", proc)
    print(f"chaos_smoke: slow-loris -> {got or '<closed>'}")

    # ---- 3. mid-stream disconnects: pages freed, requests cancelled
    pre = stats(port)["service"]["cancelled"]
    for _ in range(2):
        seen = faults.http_disconnect_mid_stream(
            HOST, port, {"prompt_len": 16, "max_new_tokens": 40},
            after_tokens=2)
        if seen < 1:
            fail("disconnect client saw no tokens before vanishing", proc)
    st = wait_pages_zero(port)
    if st["service"]["cancelled"] < pre + 2:
        fail(f"cancelled {st['service']['cancelled']} < {pre + 2} after "
             f"2 disconnects: {st}", proc)
    print(f"chaos_smoke: 2 disconnects cancelled "
          f"(cancelled={st['service']['cancelled']}), pages back to 0")

    # ---- 4. page exhaustion under concurrency: the starved arena cannot
    # hold 6 deep requests; every stream must still terminate with one
    # done/error event and no page may leak
    results, lock = [], threading.Lock()

    def one_stream():
        try:
            head, events = post(port, {"prompt_len": 40,
                                       "max_new_tokens": 24})
            terminal = [n for n, _ in events if n in ("done", "error")]
            with lock:
                results.append((head.split("\r\n")[0], terminal))
        except Exception as e:   # noqa: BLE001 — recorded and asserted on
            with lock:
                results.append((f"EXC {e!r}", []))

    threads = [threading.Thread(target=one_stream) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(STREAM_TIMEOUT_S)
    errors = 0
    for head_line, terminal in results:
        if "200" in head_line:
            if len(terminal) != 1:
                fail(f"stream terminal events {terminal} != exactly one",
                     proc)
            errors += terminal[0] == "error"
        elif "429" not in head_line:   # saturation shed is legal here
            fail(f"exhaustion stream got {head_line!r}", proc)
    st = wait_pages_zero(port)
    print(f"chaos_smoke: exhaustion survived — {len(results)} streams, "
          f"{errors} error-isolated, engine faults="
          f"{st['engine']['faults']}, pages back to 0")

    # ---- 5. deadline storm: tiny deadlines are shed (429) or expire —
    # never a 5xx, never a hang
    storm_codes = []
    for dl in faults.storm_deadlines(seed=7, n=8, lo_s=0.01, hi_s=0.2):
        head, events = post(port, {"prompt_len": 24, "max_new_tokens": 16,
                                   "deadline_s": round(dl, 3)})
        code = head.split("\r\n")[0].split(" ")[1]
        storm_codes.append(code)
        if code not in ("200", "429"):
            fail(f"deadline storm got {code}", proc)
    st = wait_pages_zero(port)
    print(f"chaos_smoke: deadline storm codes={storm_codes}, "
          f"expired={st['service']['expired']}, "
          f"shed_infeasible={st['service']['shed_infeasible']}")

    # ---- 6. SIGTERM mid-load: in-flight streams drain, exit 0
    live = []

    def draining_stream():
        head, events = post(port, {"prompt_len": 16, "max_new_tokens": 48})
        with lock:
            live.append((head.split("\r\n")[0], [n for n, _ in events]))

    threads = [threading.Thread(target=draining_stream) for _ in range(2)]
    for t in threads:
        t.start()
    time.sleep(1.0)                   # let them admit and start decoding
    proc.send_signal(signal.SIGTERM)
    for t in threads:
        t.join(STREAM_TIMEOUT_S)
    try:
        out, _ = proc.communicate(timeout=EXIT_TIMEOUT_S)
    except subprocess.TimeoutExpired:
        fail(f"server did not exit within {EXIT_TIMEOUT_S}s of SIGTERM",
             proc)
    print(f"[server] {out.strip()}" if out.strip() else
          "[server] <no further output>")
    if proc.returncode != 0:
        fail(f"exit code {proc.returncode} after SIGTERM (want 0)")
    if "drained cleanly" not in out:
        fail(f"no 'drained cleanly' line in shutdown output: {out!r}")
    for head_line, names in live:
        if "200" in head_line and (not names or
                                   names[-1] not in ("done", "error")):
            fail(f"mid-drain stream ended without terminal event: {names}")
    print("chaos_smoke: OK (malformed 4xx, slow-loris 408, disconnect "
          "cancel, exhaustion isolation, deadline storm, SIGTERM drain, "
          "zero leaked pages)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
