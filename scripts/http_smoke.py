"""CI http-smoke: boot ``serve --http``, stream one SSE request end to end,
assert the wire framing, then SIGTERM and assert a clean drain + exit 0.

  PYTHONPATH=src python scripts/http_smoke.py

What it proves (the §13 shutdown/streaming contract, over a real socket
against a real subprocess — the loopback unit tests cover the in-process
path):

  * the server comes up and prints its bound port (``--port 0``);
  * POST /v1/generate answers 200 text/event-stream with N ``token``
    events (indices 0..N-1) followed by exactly one ``done`` event;
  * GET /metrics scraped MID-STREAM (after the first token, before done)
    serves valid Prometheus text exposition covering every metric family
    the telemetry schema declares — the observability contract of §16;
  * /healthz reports the completed request;
  * SIGTERM drains and the process exits 0 with the drain log line.
"""
from __future__ import annotations

import atexit
import json
import pathlib
import re
import signal
import socket
import subprocess
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
from repro.telemetry import parse_exposition, schema  # noqa: E402

# the server child must NEVER outlive this script: a leaked `serve` process
# steals CPU from everything that runs after it (it once polluted hours of
# bench numbers). atexit covers every fail() path; the SIGTERM handler
# turns an external timeout kill into a normal exit so atexit still runs.
_children: list = []


def _reap() -> None:
    for p in _children:
        if p.poll() is None:
            p.kill()
            p.wait()


atexit.register(_reap)
signal.signal(signal.SIGTERM, lambda *_a: sys.exit(143))

NEW_TOKENS = 6
BOOT_TIMEOUT_S = 420          # model init + warmup jit compile on cold CPU
STREAM_TIMEOUT_S = 120
EXIT_TIMEOUT_S = 60


def fail(msg: str, proc=None) -> None:
    print(f"http_smoke: FAIL: {msg}")
    if proc is not None:
        proc.kill()
        out = proc.stdout.read() if proc.stdout else ""
        print(f"--- server output ---\n{out}")
    raise SystemExit(1)


def http_exchange(port: int, request: bytes, timeout_s: float) -> bytes:
    with socket.create_connection(("127.0.0.1", port),
                                  timeout=timeout_s) as s:
        s.sendall(request)
        chunks = []
        while True:
            b = s.recv(65536)
            if not b:
                break
            chunks.append(b)
    return b"".join(chunks)


def stream_and_scrape(port: int, request: bytes, timeout_s: float):
    """Send the generate request, and as soon as the first ``event:
    token`` frame lands — i.e. while the stream is live and the request
    is mid-flight — scrape ``GET /metrics`` over a second connection.
    Returns (full SSE bytes, exposition text scraped mid-stream)."""
    scraped = None
    with socket.create_connection(("127.0.0.1", port),
                                  timeout=timeout_s) as s:
        s.sendall(request)
        buf = bytearray()
        while True:
            b = s.recv(65536)
            if not b:
                break
            buf += b
            if scraped is None and b"event: token" in buf:
                raw = http_exchange(
                    port, b"GET /metrics HTTP/1.1\r\nHost: s\r\n\r\n", 30)
                head, _, body = raw.partition(b"\r\n\r\n")
                if not head.startswith(b"HTTP/1.1 200"):
                    fail(f"/metrics status: {head.splitlines()[0]!r}")
                if b"text/plain" not in head or b"version=0.0.4" not in head:
                    fail(f"/metrics content type missing exposition tag: "
                         f"{head!r}")
                scraped = body.decode()
    return bytes(buf), scraped


def check_exposition(text: str) -> int:
    """Strict-parse the scrape and assert every declared metric family is
    present with a TYPE line (parse_exposition raises on malformed
    lines — that IS the format validation)."""
    parsed = parse_exposition(text)
    missing = [n for n in schema.metric_names()
               if n not in parsed["types"]]
    if missing:
        fail(f"/metrics missing declared families: {missing}")
    submitted = parsed["samples"].get(
        (schema.SERVICE_PREFIX + "submitted", ()))
    if not submitted or submitted < 1:
        fail(f"/metrics mid-stream shows submitted={submitted!r}, "
             f"expected >= 1 (the streaming request itself)")
    return len(parsed["types"])


def parse_sse(raw: bytes):
    head, _, payload = raw.partition(b"\r\n\r\n")
    events = []
    for block in payload.decode().strip().split("\n\n"):
        lines = dict(line.split(": ", 1) for line in block.splitlines())
        events.append((lines["event"], json.loads(lines["data"])))
    return head.decode(), events


def main() -> int:
    cmd = [sys.executable, "-u", "-m", "repro.launch.serve",
           "--arch", "qwen3-0.6b", "--smoke", "--engine", "--http",
           "--port", "0", "--queue-depth", "4"]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    _children.append(proc)
    port, t0 = None, time.monotonic()
    for line in proc.stdout:
        print(f"[server] {line.rstrip()}")
        m = re.search(r"listening on http://[\d.]+:(\d+)", line)
        if m:
            port = int(m.group(1))
            break
        if time.monotonic() - t0 > BOOT_TIMEOUT_S:
            fail(f"no listen line within {BOOT_TIMEOUT_S}s", proc)
        if proc.poll() is not None:
            fail(f"server exited {proc.returncode} before listening", proc)
    if port is None:
        fail("server stdout closed before the listen line", proc)
    print(f"http_smoke: server up on port {port} "
          f"({time.monotonic() - t0:.0f}s boot)")

    body = json.dumps({"prompt_len": 12,
                       "max_new_tokens": NEW_TOKENS}).encode()
    raw, exposition = stream_and_scrape(port, (
        f"POST /v1/generate HTTP/1.1\r\nHost: s\r\n"
        f"Content-Length: {len(body)}\r\n\r\n").encode() + body,
        STREAM_TIMEOUT_S)
    if exposition is None:
        fail("stream finished without a mid-stream /metrics scrape", proc)
    n_families = check_exposition(exposition)
    print(f"http_smoke: mid-stream /metrics OK ({n_families} families, "
          f"all {len(schema.metric_names())} declared present)")
    head, events = parse_sse(raw)
    if not head.startswith("HTTP/1.1 200"):
        fail(f"status line: {head.splitlines()[0]!r}", proc)
    if "text/event-stream" not in head:
        fail(f"not an SSE response: {head!r}", proc)
    names = [n for n, _ in events]
    if names != ["token"] * NEW_TOKENS + ["done"]:
        fail(f"event framing {names} != {NEW_TOKENS}x token + done", proc)
    idxs = [d["index"] for n, d in events if n == "token"]
    if idxs != list(range(NEW_TOKENS)):
        fail(f"token indices {idxs} not 0..{NEW_TOKENS - 1}", proc)
    done = events[-1][1]
    if done["finish_reason"] != "length" or done["n_tokens"] != NEW_TOKENS:
        fail(f"done event {done} (want finish_reason=length "
             f"n_tokens={NEW_TOKENS})", proc)
    print(f"http_smoke: streamed {NEW_TOKENS} tokens + done "
          f"(ttft={done['ttft_ms']:.0f}ms latency={done['latency_ms']:.0f}ms)")

    raw = http_exchange(port, b"GET /healthz HTTP/1.1\r\nHost: s\r\n\r\n",
                        30)
    health = json.loads(raw.partition(b"\r\n\r\n")[2])
    if health["status"] != "ok" or health["service"]["completed"] != 1:
        fail(f"healthz {health}", proc)

    proc.send_signal(signal.SIGTERM)
    try:
        out, _ = proc.communicate(timeout=EXIT_TIMEOUT_S)
    except subprocess.TimeoutExpired:
        fail(f"server did not exit within {EXIT_TIMEOUT_S}s of SIGTERM",
             proc)
    print(f"[server] {out.strip()}" if out.strip() else
          "[server] <no further output>")
    if proc.returncode != 0:
        fail(f"exit code {proc.returncode} after SIGTERM (want 0)")
    if "drained cleanly" not in out:
        fail(f"no 'drained cleanly' line in shutdown output: {out!r}")
    print("http_smoke: OK (SSE framing, healthz, SIGTERM drain, exit 0)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
