"""Validate a BENCH_pr.json perf-trajectory file (CI gate).

  python scripts/check_bench.py BENCH_pr.json

Fails (exit 1) on: missing/unparseable file, wrong schema tag, zero rows,
bench errors recorded, or a serving payload with non-positive throughput /
inverted percentiles / missing artifact bytes. CI uploads the file only
after this gate passes, so the uploaded trajectory is never silently empty.
"""
from __future__ import annotations

import json
import pathlib
import sys

BENCH_SCHEMA = "repro-bench/v1"
SERVING_SCHEMA = "repro-bench-serving/v1"
SERVING_REQUIRED = ("tokens_per_s", "latency_p50_ms", "latency_p95_ms",
                    "ttft_p50_ms", "ttft_p95_ms", "param_bytes")


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}")
    raise SystemExit(1)


def check_serving(s: dict) -> None:
    if s.get("schema") != SERVING_SCHEMA:
        fail(f"serving schema {s.get('schema')!r} != {SERVING_SCHEMA!r}")
    variants = s.get("variants") or {}
    if not variants:
        fail("serving payload has no variants")
    for name, v in variants.items():
        for key in SERVING_REQUIRED:
            if not isinstance(v.get(key), (int, float)):
                fail(f"serving variant {name!r} missing numeric {key!r}")
        if v["tokens_per_s"] <= 0:
            fail(f"serving variant {name!r}: tokens_per_s <= 0")
        if v["latency_p95_ms"] < v["latency_p50_ms"]:
            fail(f"serving variant {name!r}: p95 < p50")
    if "hqp_int8" in variants:
        ab = variants["hqp_int8"].get("artifact_bytes")
        if not isinstance(ab, int) or ab <= 0:
            fail("hqp_int8 variant missing positive artifact_bytes")


def main(argv) -> int:
    if len(argv) != 1:
        fail("usage: check_bench.py BENCH_pr.json")
    path = pathlib.Path(argv[0])
    if not path.exists():
        fail(f"{path} does not exist")
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")
    if doc.get("schema") != BENCH_SCHEMA:
        fail(f"schema {doc.get('schema')!r} != {BENCH_SCHEMA!r}")
    rows = doc.get("rows") or []
    if not rows:
        fail("no benchmark rows")
    for r in rows:
        if not isinstance(r.get("name"), str) or "us_per_call" not in r:
            fail(f"malformed row: {r!r}")
        if str(r.get("derived", "")).startswith("ERROR:"):
            fail(f"row recorded an error: {r['name']}")
    if doc.get("errors"):
        fail(f"bench errors: {doc['errors']}")
    if "serving" in doc:
        check_serving(doc["serving"])
    n_serving = sum(r["name"].startswith("serving/") for r in rows)
    print(f"check_bench: OK ({len(rows)} rows, {n_serving} serving, "
          f"benches={doc.get('benches')})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
