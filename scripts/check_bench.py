"""Validate a BENCH_pr.json perf-trajectory file (CI gate).

  python scripts/check_bench.py BENCH_pr.json

Fails (exit 1) on: missing/unparseable file, wrong schema tag, zero rows,
bench errors recorded, a serving payload with non-positive throughput /
inverted percentiles / missing artifact bytes (variants with zero completed
requests are tolerated — they report a zeroed summary, not a crash), a
serving payload missing a variant its benches declared in
``expected_variants`` (a NAMED failure, not a KeyError), a ``speculative``
variant whose acceptance rate falls below SPEC_ACCEPT_MIN or whose
tokens/s does not beat its same-workload bf16 ``decode_steps=4`` baseline
(HQP's Δacc bound is what makes the artifact a high-acceptance drafter —
acceptance and the bit-identical-output speedup are the two headline
numbers), a ``paged`` variant slower than PAGED_MIN_RATIO x its contiguous
``paged_baseline`` or a ``paged_shared`` variant whose peak cache bytes
exceed PAGED_BYTES_MAX x the contiguous footprint / whose prefix cache
never hit (paging must be free when nothing is shared and a strict memory
win when a system prompt repeats), an ``http_stream`` variant whose
goodput falls below HTTP_MIN_RATIO x the in-process tokens/s or that shed
or deadline-expired anything on its fully-admittable closed-loop workload,
an ``http_overload`` sweep with a deadline violation at any no-shed point
(below the knee the service must meet every SLO), a below-knee point
shedding more than HTTP_LOW_SHED_MAX, or a sweep that never sheds at all
(never reached the knee), a ``chaos`` variant whose injected faults leaked
a page / perturbed a surviving stream's tokens / killed the pump / blew
the survivor p95 past CHAOS_P95_MAX x fault-free (each fault's blast
radius must stay request-scoped), an ``admission_feasible`` variant that
failed to shed an infeasible deadline at submit, let an admitted request
expire, or starved the feasible half of its storm (the predictor must
reject the impossible without rejecting the possible),
a ``decode_attention/xla_win/*`` or ``prefill_attention/xla_win/*``
sweep whose ms/step (ms/chunk) grows more than FLAT_MAX from the smallest
to the largest ``max_seq`` — the windowed attends must scale with live
length, not cache capacity — or a prefill primitive costing more than
PREFILL_RATIO_MAX x the WINDOWED einsum at every sweep point (the
``xla_einsum`` rows time the windowed masked einsum — exactly the engine
prefill hot path the primitive replaced; it may never be slower than what
it replaced, judged at the least-noisy point since the comparison is
length-independent on both sides). Gates read xla rows only; absent ``ref``
rows (interpreter-overhead timings, or a bench subset that skipped them)
are tolerated. CI uploads the file only after this gate passes, so the
uploaded trajectory is never silently empty.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

BENCH_SCHEMA = "repro-bench/v1"
SERVING_SCHEMA = "repro-bench-serving/v1"
SERVING_REQUIRED = ("tokens_per_s", "latency_p50_ms", "latency_p95_ms",
                    "ttft_p50_ms", "ttft_p95_ms", "param_bytes")
SPEC_REQUIRED = ("acceptance_rate", "drafted_tokens", "accepted_tokens",
                 "baseline_tokens_per_s")
DECODE_WIN_ROW = re.compile(r"^decode_attention/xla_win/S(\d+)$")
PREFILL_WIN_ROW = re.compile(r"^prefill_attention/xla_win/S(\d+)$")
PREFILL_EINSUM_ROW = re.compile(r"^prefill_attention/xla_einsum/S(\d+)$")
FLAT_MAX = 1.3
PREFILL_RATIO_MAX = 1.1
SPEC_ACCEPT_MIN = 0.7
PAGED_MIN_RATIO = 0.90     # was 0.95 while the contiguous baseline paid a
                           # full-pool copy per bf16 DUS write; with the
                           # uint16 store fix the baseline is honest and
                           # paging's real cost — one page-table gather per
                           # attend — measures ~0.95x, so the floor keeps
                           # ~5% of slack instead of zero
PAGED_BYTES_MAX = 0.6
HTTP_MIN_RATIO = 0.9        # http_stream goodput vs in-process tokens/s
HTTP_LOW_SHED_MAX = 0.25    # shed-rate ceiling at the below-knee sweep point
TELEMETRY_MAX_OVERHEAD = 0.03   # metrics/histogram plane may cost at most
                                # 3% of http_stream tokens/s vs the
                                # telemetry-off control phase
CHAOS_P95_MAX = 2.0         # survivor p95 vs fault-free p95; survivors
                            # usually run FASTER (faulted slots free early),
                            # so this only catches a fault-handling stall


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}")
    raise SystemExit(1)


def check_serving(s: dict) -> None:
    if s.get("schema") != SERVING_SCHEMA:
        fail(f"serving schema {s.get('schema')!r} != {SERVING_SCHEMA!r}")
    variants = s.get("variants") or {}
    if not variants:
        fail("serving payload has no variants")
    for name in s.get("expected_variants") or []:
        if name not in variants:
            fail(f"serving payload missing expected variant {name!r} "
                 f"(have: {sorted(variants)}) — a bench declared it but "
                 f"never wrote it")
    for name, v in variants.items():
        for key in SERVING_REQUIRED:
            if not isinstance(v.get(key), (int, float)):
                fail(f"serving variant {name!r}: {key!r} must be numeric, "
                     f"got {v.get(key)!r}")
        if v.get("n_requests") == 0:
            continue    # zeroed summary from an empty result set is valid
        if v["tokens_per_s"] <= 0:
            fail(f"serving variant {name!r}: tokens_per_s = "
                 f"{v['tokens_per_s']!r}, threshold > 0")
        if v["latency_p95_ms"] < v["latency_p50_ms"]:
            fail(f"serving variant {name!r}: latency_p95_ms "
                 f"{v['latency_p95_ms']:.3f} < latency_p50_ms "
                 f"{v['latency_p50_ms']:.3f} — percentiles inverted")
    if "hqp_int8" in variants:
        ab = variants["hqp_int8"].get("artifact_bytes")
        if not isinstance(ab, int) or ab <= 0:
            fail(f"hqp_int8 variant: artifact_bytes = {ab!r}, "
                 f"threshold: positive int")
    if "speculative" in variants:
        check_speculative(variants)
    if "paged" in variants or "paged_shared" in variants:
        check_paged(variants)
    if "http_stream" in variants or "http_overload" in variants:
        check_http(variants)
    if "chaos" in variants or "admission_feasible" in variants:
        check_chaos(variants)


def check_speculative(variants: dict) -> None:
    """The two headline speculative numbers, gated:

    * acceptance rate >= SPEC_ACCEPT_MIN — the drafter is only useful
      because HQP's quality bound keeps it agreeing with its bf16 parent;
      a collapse here means the artifact regressed as a drafter even if
      raw tokens/s looks fine;
    * tokens/s > the bf16 ``decode_steps=4`` baseline timed on the SAME
      workload in the same interleaved bench run (the ``spec_baseline``
      variant when present, else the recorded ``baseline_tokens_per_s``)
      — greedy speculative output is bit-identical to serial bf16, so
      anything short of a strict win means the subsystem is pure
      overhead."""
    v = variants["speculative"]
    if v.get("n_requests") == 0:
        fail("speculative variant completed zero requests")
    for key in SPEC_REQUIRED:
        if not isinstance(v.get(key), (int, float)):
            fail(f"speculative variant missing numeric {key!r}")
    if v["acceptance_rate"] < SPEC_ACCEPT_MIN:
        fail(f"speculative acceptance rate {v['acceptance_rate']:.3f} < "
             f"{SPEC_ACCEPT_MIN} floor ({v['accepted_tokens']}/"
             f"{v['drafted_tokens']} drafts accepted) — the HQP drafter "
             f"no longer tracks its bf16 parent")
    base = variants.get("spec_baseline") or {}
    base_tok_s = (base.get("tokens_per_s")
                  if isinstance(base.get("tokens_per_s"), (int, float))
                  and base.get("n_requests") else
                  v["baseline_tokens_per_s"])
    if v["tokens_per_s"] <= base_tok_s:
        fail(f"speculative tokens/s {v['tokens_per_s']:.1f} does not beat "
             f"the bf16 decode_steps=4 baseline {base_tok_s:.1f} on the "
             f"same workload — speculation must be a strict win, its "
             f"greedy output is bit-identical")
    print(f"check_bench: speculative OK (accept="
          f"{v['acceptance_rate']:.2f} >= {SPEC_ACCEPT_MIN}, "
          f"{v['tokens_per_s']:.0f} tok/s vs bf16 {base_tok_s:.0f}, "
          f"{v['tokens_per_s'] / max(base_tok_s, 1e-9):.2f}x)")


def check_paged(variants: dict) -> None:
    """The two paged-KV headline numbers, gated:

    * throughput parity — paging is bookkeeping (same kernels, one extra
      page-table gather), so the ``paged`` variant's tokens/s on the
      NO-SHARING workload must stay >= PAGED_MIN_RATIO x its contiguous
      ``paged_baseline`` timed in the same interleaved bench run; anything
      worse means the indirection leaked into the hot path;
    * memory win — on the repeated-system-prompt workload the arena only
      holds mapped pages and the shared head is mapped ONCE, so
      ``paged_shared``'s ``kv_bytes_peak`` must be <= PAGED_BYTES_MAX x the
      contiguous footprint for the same (n_slots, max_seq), and the prefix
      cache must actually fire (>= 1 hit, prefilled < total prompt
      tokens) — a silent cache miss would still pass the throughput gate."""
    for name in ("paged", "paged_baseline", "paged_shared"):
        if name not in variants:
            fail(f"paged gate needs variant {name!r} "
                 f"(have: {sorted(variants)}) — bench_paged writes all "
                 f"three; a partial payload means the bench died mid-run")
    v, base = variants["paged"], variants["paged_baseline"]
    if v.get("n_requests") == 0:
        fail("paged variant completed zero requests")
    ratio = v["tokens_per_s"] / max(base["tokens_per_s"], 1e-9)
    if ratio < PAGED_MIN_RATIO:
        fail(f"paged tokens/s {v['tokens_per_s']:.1f} is {ratio:.3f}x the "
             f"contiguous baseline {base['tokens_per_s']:.1f} (floor "
             f"{PAGED_MIN_RATIO}x) — the page-table gather is no longer "
             f"free")
    s = variants["paged_shared"]
    for key in ("prefix_hits", "prefill_tokens", "prompt_tokens",
                "kv_bytes_peak", "contiguous_kv_bytes"):
        if not isinstance(s.get(key), (int, float)):
            fail(f"paged_shared variant missing numeric {key!r}")
    if s["prefix_hits"] < 1:
        fail("paged_shared recorded zero prefix hits — every timed request "
             "repeats the system prompt, the warm cache must hit")
    if s["prefill_tokens"] >= s["prompt_tokens"]:
        fail(f"paged_shared prefilled {s['prefill_tokens']} of "
             f"{s['prompt_tokens']} prompt tokens — prefix reuse saved "
             f"nothing")
    bratio = s["kv_bytes_peak"] / max(s["contiguous_kv_bytes"], 1e-9)
    if bratio > PAGED_BYTES_MAX:
        fail(f"paged_shared kv_bytes_peak {s['kv_bytes_peak']} is "
             f"{bratio:.2f}x the contiguous footprint "
             f"{s['contiguous_kv_bytes']} (limit {PAGED_BYTES_MAX}x) — "
             f"shared pages are being duplicated or never freed")
    print(f"check_bench: paged OK (throughput {ratio:.2f}x contiguous >= "
          f"{PAGED_MIN_RATIO}, shared-prefix bytes {bratio:.2f}x <= "
          f"{PAGED_BYTES_MAX}, hits={s['prefix_hits']}, "
          f"prefilled {s['prefill_tokens']}/{s['prompt_tokens']})")


def check_http(variants: dict) -> None:
    """The HTTP front door's two headline guarantees, gated:

    * transport is ~free — ``http_stream`` (closed loop, same workload and
      SAME ENGINE as the in-process run timed next to it) must keep
      goodput >= HTTP_MIN_RATIO x in-process tokens/s, with zero sheds and
      zero deadline violations: asyncio + SSE framing + the pump-thread
      lock may not eat the engine's throughput;
    * overload degrades into 429s, not blown SLOs — in the
      ``http_overload`` open-loop sweep, every point that shed nothing
      must also have violated no deadline, the below-knee (lowest-rate)
      point must stay under HTTP_LOW_SHED_MAX shed rate with zero
      violations, and at least one point must actually shed — a sweep
      that never reaches the knee proves nothing about admission
      control."""
    for name in ("http_stream", "http_overload"):
        if name not in variants:
            fail(f"http gate needs variant {name!r} (have: "
                 f"{sorted(variants)}) — bench_http writes both; a partial "
                 f"payload means the bench died mid-run")
    v = variants["http_stream"]
    for key in ("goodput_ratio", "inproc_tokens_per_s", "shed",
                "deadline_violations"):
        if not isinstance(v.get(key), (int, float)):
            fail(f"http_stream: {key!r} must be numeric, got {v.get(key)!r}")
    if v["goodput_ratio"] < HTTP_MIN_RATIO:
        fail(f"http_stream goodput {v['tokens_per_s']:.1f} tok/s is "
             f"{v['goodput_ratio']:.3f}x the in-process "
             f"{v['inproc_tokens_per_s']:.1f} tok/s (floor "
             f"{HTTP_MIN_RATIO}x) — the SSE transport is eating engine "
             f"throughput")
    if v["shed"] != 0:
        fail(f"http_stream shed {v['shed']} requests, threshold 0 — the "
             f"closed-loop queue is sized to admit every client")
    if v["deadline_violations"] != 0:
        fail(f"http_stream had {v['deadline_violations']} deadline "
             f"violations, threshold 0 — no deadlines are set on this "
             f"workload")
    # telemetry-overhead gate: the registry-reads-live-dicts design means
    # the metrics plane must be ~free on the hot path; the bench times a
    # telemetry-off control interleaved with the instrumented phase
    if "telemetry_overhead_ratio" in v:
        r = v["telemetry_overhead_ratio"]
        if not isinstance(r, (int, float)):
            fail(f"http_stream: telemetry_overhead_ratio must be numeric, "
                 f"got {r!r}")
        if r < 1.0 - TELEMETRY_MAX_OVERHEAD:
            fail(f"http_stream with telemetry runs at {r:.3f}x the "
                 f"telemetry-off goodput "
                 f"({v['tokens_per_s']:.1f} vs "
                 f"{v.get('tokens_per_s_telemetry_off', 0):.1f} tok/s; "
                 f"floor {1.0 - TELEMETRY_MAX_OVERHEAD:.2f}x) — the "
                 f"metrics/span plane leaked into the hot path")
    o = variants["http_overload"]
    sweep = o.get("sweep") or []
    if len(sweep) < 2:
        fail(f"http_overload sweep has {len(sweep)} point(s); need >= 2 "
             f"(below and above the knee)")
    for p in sweep:
        for key in ("offered_rps", "shed", "shed_rate",
                    "deadline_violations"):
            if not isinstance(p.get(key), (int, float)):
                fail(f"http_overload sweep point {p.get('offered_mult')}: "
                     f"{key!r} must be numeric, got {p.get(key)!r}")
        if p["shed"] == 0 and p["deadline_violations"] != 0:
            fail(f"http_overload point at {p['offered_rps']:.0f} rps shed "
                 f"nothing yet violated {p['deadline_violations']} "
                 f"deadline(s), threshold 0 — below the knee every "
                 f"admitted request must meet its SLO")
    low = min(sweep, key=lambda p: p["offered_rps"])
    if low["shed_rate"] > HTTP_LOW_SHED_MAX:
        fail(f"http_overload below-knee point ({low['offered_rps']:.0f} "
             f"rps) shed rate {low['shed_rate']:.2f} > "
             f"{HTTP_LOW_SHED_MAX} ceiling — admission control is "
             f"rejecting load the engine can carry")
    if low["deadline_violations"] != 0:
        fail(f"http_overload below-knee point ({low['offered_rps']:.0f} "
             f"rps) violated {low['deadline_violations']} deadline(s), "
             f"threshold 0")
    if not any(p["shed"] > 0 for p in sweep):
        fail(f"http_overload never shed (sheds="
             f"{[p['shed'] for p in sweep]}) — the sweep must cross the "
             f"knee to prove the admission bound engages")
    tele = ""
    if "telemetry_overhead_ratio" in v:
        tele = (f", telemetry {v['telemetry_overhead_ratio']:.3f}x off >= "
                f"{1.0 - TELEMETRY_MAX_OVERHEAD:.2f}")
    print(f"check_bench: http OK (stream goodput "
          f"{v['goodput_ratio']:.2f}x inproc >= {HTTP_MIN_RATIO}, "
          f"overload sheds={[p['shed'] for p in sweep]} "
          f"violations={[p['deadline_violations'] for p in sweep]} over "
          f"{len(sweep)} points{tele})")


def check_chaos(variants: dict) -> None:
    """The fault-tolerance contract, gated (every check prints or fails
    with measured-vs-threshold):

    * every injected fault's blast radius is ONE request — the pump
      survives, no page leaks, and every surviving stream's tokens are
      bit-identical to the fault-free reference run;
    * survivor p95 stays within CHAOS_P95_MAX x fault-free (fault
      handling must not stall the batch — survivors usually get FASTER
      because faulted slots free early);
    * the feasibility predictor sheds impossible deadlines at submit
      (with an honest positive Retry-After) while the generous-deadline
      half of the same storm completes with zero expiries."""
    for name in ("chaos", "admission_feasible"):
        if name not in variants:
            fail(f"chaos gate needs variant {name!r} (have: "
                 f"{sorted(variants)}) — bench_chaos writes both; a "
                 f"partial payload means the bench died mid-run")
    v = variants["chaos"]
    for key in ("faults", "leaked_pages", "survivors",
                "survivors_identical", "pump_survived", "p95_ratio"):
        if not isinstance(v.get(key), (int, float)):
            fail(f"chaos: {key!r} must be numeric, got {v.get(key)!r}")
    if v["faults"] < 1:
        fail(f"chaos: faults = {v['faults']}, threshold >= 1 — the "
             f"injectors never fired, the run proved nothing")
    if v["pump_survived"] != 1:
        fail(f"chaos: pump_survived = {v['pump_survived']}, threshold 1 — "
             f"an injected per-request fault escaped and killed the "
             f"serving loop")
    if v["leaked_pages"] != 0:
        fail(f"chaos: leaked_pages = {v['leaked_pages']}, threshold 0 — "
             f"a faulted/cancelled request did not release its KV pages")
    if v["survivors"] < 1:
        fail(f"chaos: survivors = {v['survivors']}, threshold >= 1 — "
             f"every request died, isolation is indistinguishable from "
             f"blast radius")
    if v["survivors_identical"] != 1:
        fail(f"chaos: survivors_identical = {v['survivors_identical']}, "
             f"threshold 1 — a neighbor's fault perturbed a surviving "
             f"stream's tokens")
    if v["p95_ratio"] > CHAOS_P95_MAX:
        fail(f"chaos: survivor p95 is {v['p95_ratio']:.2f}x the "
             f"fault-free p95 {v['fault_free_p95_ms']:.0f}ms (limit "
             f"{CHAOS_P95_MAX}x) — fault handling is stalling the batch")
    a = variants["admission_feasible"]
    for key in ("shed_infeasible", "expired", "completed",
                "retry_after_s_sample"):
        if not isinstance(a.get(key), (int, float)):
            fail(f"admission_feasible: {key!r} must be numeric, got "
                 f"{a.get(key)!r}")
    if a["shed_infeasible"] < 1:
        fail(f"admission_feasible: shed_infeasible = "
             f"{a['shed_infeasible']}, threshold >= 1 — impossible "
             f"deadlines were admitted to burn slot time")
    if a["expired"] != 0:
        fail(f"admission_feasible: expired = {a['expired']}, threshold 0 "
             f"— an admitted request blew its deadline; the predictor "
             f"admitted work it could not serve")
    if a["completed"] < 1:
        fail(f"admission_feasible: completed = {a['completed']}, "
             f"threshold >= 1 — the feasible half of the storm starved")
    if a["retry_after_s_sample"] <= 0:
        fail(f"admission_feasible: retry_after_s_sample = "
             f"{a['retry_after_s_sample']}, threshold > 0 — infeasible "
             f"sheds must advertise an honest computed Retry-After")
    print(f"check_bench: chaos OK (faults={v['faults']} measured vs >= 1, "
          f"leaked_pages={v['leaked_pages']} vs 0, "
          f"survivors_identical={v['survivors_identical']} vs 1, "
          f"pump_survived={v['pump_survived']} vs 1, "
          f"p95_ratio={v['p95_ratio']:.2f} vs <= {CHAOS_P95_MAX}; "
          f"admission shed_infeasible={a['shed_infeasible']} vs >= 1, "
          f"expired={a['expired']} vs 0, completed={a['completed']} vs "
          f">= 1, retry_after={a['retry_after_s_sample']:.3f}s vs > 0)")


def _sweep(rows: list, pattern) -> dict:
    out = {}
    for r in rows:
        m = pattern.match(r.get("name", ""))
        if m:
            out[int(m.group(1))] = float(r["us_per_call"])
    return out


def check_flat(rows: list, pattern, label: str) -> int:
    """A windowed KV attend must be ~flat across the max_seq sweep: the
    whole point of the length-aware path is that cost tracks the visible
    window, not cache capacity. Gated on the xla rows only (``ref`` rows are
    Pallas-interpreter overhead, not kernel speed)."""
    win = _sweep(rows, pattern)
    if not win:
        return 0
    if len(win) < 2:
        fail(f"{label} sweep has {len(win)} xla_win row(s); "
             f"need >= 2 max_seq points to check flatness")
    lo, hi = min(win), max(win)
    ratio = win[hi] / max(win[lo], 1e-12)
    if ratio > FLAT_MAX:
        fail(f"windowed {label} is not length-aware: "
             f"S{hi} costs {ratio:.2f}x S{lo} "
             f"(limit {FLAT_MAX}x; us={win})")
    print(f"check_bench: {label} flat OK "
          f"(S{lo}->S{hi}: {ratio:.2f}x over {len(win)} points)")
    return len(win)


def check_prefill_ratio(rows: list) -> None:
    """The prefill primitive replaced the WINDOWED masked einsum as the
    engine's prefill hot path (``xla_einsum`` rows time that exact einsum,
    same window — not the full-cache contrast row); the xla primitive may
    cost at most PREFILL_RATIO_MAX x that baseline — anything more means
    the swap made TTFT worse than what it replaced. Both sides are
    window-fixed, so every max_seq sweep point measures the SAME
    length-independent comparison; the gate takes the min ratio across
    points (shared-runner noise only ever inflates one side of any single
    point — the same reasoning as the benches' min-of-reps timer), while
    genuine length-dependence is the flatness gate's job."""
    win = _sweep(rows, PREFILL_WIN_ROW)
    ein = _sweep(rows, PREFILL_EINSUM_ROW)
    if not win and not ein:
        return
    common = sorted(set(win) & set(ein))
    if not common:
        fail("prefill_attention sweep has xla_win and xla_einsum rows with "
             "no shared max_seq point to compare")
    ratios = {s: win[s] / max(ein[s], 1e-12) for s in common}
    s = min(ratios, key=ratios.get)
    if ratios[s] > PREFILL_RATIO_MAX:
        fail(f"prefill primitive is slower than the windowed einsum it "
             f"replaced: best point {ratios[s]:.2f}x at S{s} (limit "
             f"{PREFILL_RATIO_MAX}x; win_us={win} einsum_us={ein})")
    print(f"check_bench: prefill kernel-vs-einsum OK "
          f"(best {ratios[s]:.2f}x at S{s} over {len(common)} points, "
          f"limit {PREFILL_RATIO_MAX}x)")


def main(argv) -> int:
    if len(argv) != 1:
        fail("usage: check_bench.py BENCH_pr.json")
    path = pathlib.Path(argv[0])
    if not path.exists():
        fail(f"{path} does not exist")
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")
    if doc.get("schema") != BENCH_SCHEMA:
        fail(f"schema {doc.get('schema')!r} != {BENCH_SCHEMA!r}")
    rows = doc.get("rows") or []
    if not rows:
        fail("no benchmark rows")
    for r in rows:
        if not isinstance(r.get("name"), str) or "us_per_call" not in r:
            fail(f"malformed row: {r!r}")
        if str(r.get("derived", "")).startswith("ERROR:"):
            fail(f"row recorded an error: {r['name']}")
    if doc.get("errors"):
        fail(f"bench errors: {doc['errors']}")
    if "serving" in doc:
        check_serving(doc["serving"])
    n_decode = check_flat(rows, DECODE_WIN_ROW, "decode_attention")
    n_prefill = check_flat(rows, PREFILL_WIN_ROW, "prefill_attention")
    check_prefill_ratio(rows)
    n_serving = sum(r["name"].startswith("serving/") for r in rows)
    print(f"check_bench: OK ({len(rows)} rows, {n_serving} serving, "
          f"{n_decode} windowed-decode, {n_prefill} windowed-prefill, "
          f"benches={doc.get('benches')})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
