"""Validate a BENCH_pr.json perf-trajectory file (CI gate).

  python scripts/check_bench.py BENCH_pr.json

Fails (exit 1) on: missing/unparseable file, wrong schema tag, zero rows,
bench errors recorded, a serving payload with non-positive throughput /
inverted percentiles / missing artifact bytes (variants with zero completed
requests are tolerated — they report a zeroed summary, not a crash), or a
``decode_attention/xla_win/*`` sweep whose ms/step grows more than
DECODE_FLAT_MAX from the smallest to the largest ``max_seq`` — the windowed
decode path must scale with live length, not cache capacity. CI uploads the
file only after this gate passes, so the uploaded trajectory is never
silently empty.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

BENCH_SCHEMA = "repro-bench/v1"
SERVING_SCHEMA = "repro-bench-serving/v1"
SERVING_REQUIRED = ("tokens_per_s", "latency_p50_ms", "latency_p95_ms",
                    "ttft_p50_ms", "ttft_p95_ms", "param_bytes")
DECODE_WIN_ROW = re.compile(r"^decode_attention/xla_win/S(\d+)$")
DECODE_FLAT_MAX = 1.3


def fail(msg: str) -> None:
    print(f"check_bench: FAIL: {msg}")
    raise SystemExit(1)


def check_serving(s: dict) -> None:
    if s.get("schema") != SERVING_SCHEMA:
        fail(f"serving schema {s.get('schema')!r} != {SERVING_SCHEMA!r}")
    variants = s.get("variants") or {}
    if not variants:
        fail("serving payload has no variants")
    for name, v in variants.items():
        for key in SERVING_REQUIRED:
            if not isinstance(v.get(key), (int, float)):
                fail(f"serving variant {name!r} missing numeric {key!r}")
        if v.get("n_requests") == 0:
            continue    # zeroed summary from an empty result set is valid
        if v["tokens_per_s"] <= 0:
            fail(f"serving variant {name!r}: tokens_per_s <= 0")
        if v["latency_p95_ms"] < v["latency_p50_ms"]:
            fail(f"serving variant {name!r}: p95 < p50")
    if "hqp_int8" in variants:
        ab = variants["hqp_int8"].get("artifact_bytes")
        if not isinstance(ab, int) or ab <= 0:
            fail("hqp_int8 variant missing positive artifact_bytes")


def check_decode_flat(rows: list) -> int:
    """Windowed decode attention must be ~flat across the max_seq sweep: the
    whole point of the length-aware path is that cost tracks the visible
    window, not cache capacity. Gated on the xla rows only (``ref`` rows are
    Pallas-interpreter overhead, not kernel speed)."""
    win = {}
    for r in rows:
        m = DECODE_WIN_ROW.match(r.get("name", ""))
        if m:
            win[int(m.group(1))] = float(r["us_per_call"])
    if not win:
        return 0
    if len(win) < 2:
        fail(f"decode_attention sweep has {len(win)} xla_win row(s); "
             f"need >= 2 max_seq points to check flatness")
    lo, hi = min(win), max(win)
    ratio = win[hi] / max(win[lo], 1e-12)
    if ratio > DECODE_FLAT_MAX:
        fail(f"windowed decode attention is not length-aware: "
             f"S{hi} costs {ratio:.2f}x S{lo} "
             f"(limit {DECODE_FLAT_MAX}x; us={win})")
    print(f"check_bench: decode_attention flat OK "
          f"(S{lo}->S{hi}: {ratio:.2f}x over {len(win)} points)")
    return len(win)


def main(argv) -> int:
    if len(argv) != 1:
        fail("usage: check_bench.py BENCH_pr.json")
    path = pathlib.Path(argv[0])
    if not path.exists():
        fail(f"{path} does not exist")
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")
    if doc.get("schema") != BENCH_SCHEMA:
        fail(f"schema {doc.get('schema')!r} != {BENCH_SCHEMA!r}")
    rows = doc.get("rows") or []
    if not rows:
        fail("no benchmark rows")
    for r in rows:
        if not isinstance(r.get("name"), str) or "us_per_call" not in r:
            fail(f"malformed row: {r!r}")
        if str(r.get("derived", "")).startswith("ERROR:"):
            fail(f"row recorded an error: {r['name']}")
    if doc.get("errors"):
        fail(f"bench errors: {doc['errors']}")
    if "serving" in doc:
        check_serving(doc["serving"])
    n_decode = check_decode_flat(rows)
    n_serving = sum(r["name"].startswith("serving/") for r in rows)
    print(f"check_bench: OK ({len(rows)} rows, {n_serving} serving, "
          f"{n_decode} windowed-decode, benches={doc.get('benches')})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
