"""Regenerate EXPERIMENTS.md tables from experiments/ artifacts."""
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
REPRO = ROOT / "experiments" / "repro"


def fmt_t(x):
    return f"{x:.3g}"


def cell_rows(mesh_filter, variant="baseline"):
    rows = []
    for f in sorted(DRY.glob(f"*__{variant}.json")):
        r = json.loads(f.read_text())
        if r["mesh"] != mesh_filter or r["variant"] != variant:
            continue
        rows.append(r)
    return rows


def dryrun_section():
    out = ["## §Dry-run\n",
           "Every (arch × shape) cell lowered + compiled with "
           "`jax.jit(step, in_shardings=…).lower(**input_specs).compile()` "
           "on BOTH production meshes. `skipped` = long_500k on pure "
           "full-attention archs (O(S²), per the brief; DESIGN.md §4).\n"]
    for mesh in ("16x16", "2x16x16"):
        rows = cell_rows(mesh)
        ok = sum(1 for r in rows if r["status"] == "ok")
        sk = sum(1 for r in rows if r["status"] == "skipped")
        out.append(f"\n### Mesh {mesh} ({'512' if 'x16x16' in mesh and mesh.startswith('2') else '256'} chips): "
                   f"{ok} compiled OK, {sk} documented skips, "
                   f"{len(rows) - ok - sk} errors\n")
        out.append("| arch | shape | status | compile s | args GB/dev | "
                   "temp GB/dev | collectives (AG/AR/RS/A2A/CP) |")
        out.append("|---|---|---|---|---|---|---|")
        for r in rows:
            if r["status"] != "ok":
                out.append(f"| {r['arch']} | {r['shape']} | {r['status']}"
                           f" ({r.get('reason', '')[:40]}) | | | | |")
                continue
            mem = r.get("memory", {})
            arg = (mem.get("argument_bytes") or 0) / 1e9
            tmp = (mem.get("temp_bytes") or 0) / 1e9
            cc = r["roofline"]["collective_counts"]
            cstr = "/".join(str(int(cc.get(k, 0))) for k in
                            ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute"))
            out.append(f"| {r['arch']} | {r['shape']} | ok | "
                       f"{r['compile_s']:.0f} | {arg:.2f} | {tmp:.2f} | "
                       f"{cstr} |")
    return "\n".join(out)


def roofline_section():
    out = ["## §Roofline\n",
           "Per-device, per-step terms from the loop-aware HLO analyzer "
           "(`repro.roofline.hlo_cost`) over the compiled single-pod (16×16) "
           "artifact. Hardware: TPU v5e — 197 TFLOP/s bf16 (394 int8), "
           "819 GB/s HBM, 50 GB/s/link ICI.\n",
           "* `compute` = HLO dot FLOPs / peak (int8 dots at int8 peak)",
           "* `memory` = HLO bytes / HBM bw (slice/in-place aware)",
           "* `collective` = Σ collective result bytes / ICI bw",
           "* `useful` = MODEL_FLOPS (6·N_active·D train, 2·N_active·D "
           "inference) / (HLO FLOPs × chips) — remat/waste detector",
           "* `roofline fraction` = useful compute time / dominant term\n",
           "| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | roofline frac | bottleneck note |",
           "|---|---|---|---|---|---|---|---|---|"[:-4],
           ]
    notes = {
        ("arctic-480b", "train_4k"): "opt-state layout + GQA score all-reduce: FIXED in §Perf (197->26s)",
        ("xlstm-1.3b", "train_4k"): "idle model axis: FIXED in §Perf via pure-DP (32->8.8s)",
        ("xlstm-1.3b", "prefill_32k"): "sLSTM per-timestep scan + idle model axis (pure-DP transfers)",
        ("jamba-1.5-large-398b", "long_500k"): "B=1 decode: per-step mamba-state gathers; shard d_inner",
        ("jamba-1.5-large-398b", "decode_32k"): "mamba-state + KV gathers; INT8 KV + state sharding",
        ("command-r-35b", "decode_32k"): "KV reads: INT8 cache ~halves (granite §Perf twin)",
        ("command-r-35b", "prefill_32k"): "f32 score tiles: context-parallel attn -30% coll (opt2 cell)",
        ("granite-3-8b", "decode_32k"): "HILLCLIMBED §Perf: HQP INT8 W+KV+vocab pad -> 3.23x",
        ("arctic-480b", "decode_32k"): "EP dispatch all-gathers at B=8/dev; INT8 experts halve",
        ("phi3.5-moe-42b-a6.6b", "decode_32k"): "EP dispatch + KV; INT8 both",
        ("musicgen-medium", "decode_32k"): "MHA (kv=24) cache reads: INT8 KV halves",
        ("phi-3-vision-4.2b", "decode_32k"): "MHA cache reads: INT8 KV halves",
        ("stablelm-1.6b", "decode_32k"): "MHA cache reads: INT8 KV halves",
        ("qwen3-0.6b", "decode_32k"): "tiny model over-sharded: fewer chips or batch-major",
        ("qwen3-0.6b", "train_4k"): "d_model/16=64-wide shards: activation-bound; reduce TP",
        ("xlstm-1.3b", "decode_32k"): "mLSTM C-matrix reads (hd=1024): head/state sharding",
        ("xlstm-1.3b", "long_500k"): "recurrent decode is state-read bound (good: O(1) in S)",
    }
    auto = {"memory": "activation/weight traffic: fuse, bf16 intermediates, INT8 (HQP)",
            "collective": "FSDP gathers / score reductions: see §Perf levers",
            "compute": "near compute roof"}
    for r in cell_rows("16x16"):
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | | | {r.get('reason','')[:50]} |")
            continue
        rl = r["roofline"]
        frac = min(rl.get("roofline_fraction", 0.0), 1.0)
        note = notes.get((r["arch"], r["shape"]),
                         auto.get(rl["dominant"][2:], ""))
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(rl['t_compute'])} | "
            f"{fmt_t(rl['t_memory'])} | {fmt_t(rl['t_collective'])} | "
            f"{rl['dominant'][2:]} | {rl['useful_flops_ratio']:.2f} | "
            f"{frac:.2f} | {note} |")
    return "\n".join(out)


def repro_section():
    out = ["## §Repro — paper Tables I & II (faithful reproduction)\n"]
    for arch, paper in (("mobilenetv3s", "Table I (MobileNetV3)"),
                        ("resnet18", "Table II (ResNet-18)")):
        f = REPRO / f"{arch}.json"
        if not f.exists():
            out.append(f"### {paper}: PENDING (run repro_exp.cnn_experiment)")
            continue
        t = json.loads(f.read_text())
        out.append(f"\n### {paper} — baseline acc "
                   f"{t['baseline_accuracy']:.4f} (synthetic val, Δ_ax="
                   f"{t['delta_ax']:.1%})\n")
        out.append("| method | modeled speedup | size reduction | "
                   "acc drop | θ | compliant |")
        out.append("|---|---|---|---|---|---|")
        for r in t["rows"]:
            sp = t["speedups_modeled"][r["method"]]
            out.append(f"| {r['method']} | {sp:.2f}× | "
                       f"{r['size_reduction']:.0%} | {r['drop']*100:+.2f}% | "
                       f"{r['theta']:.0%} | "
                       f"{'✓' if r['compliant'] else '✗ VIOLATES'} |")
        fam = t.get("hqp_sparsity_by_family", {})
        if fam:
            thetas = {k: v["theta"] for k, v in fam.items()}
            mx = max(thetas, key=thetas.get)
            mn = min(thetas, key=thetas.get)
            out.append(f"\nLayer-wise θ (§V-C): max {thetas[mx]:.0%} at `{mx}`"
                       f", min {thetas[mn]:.0%} at `{mn}` — non-uniform, as "
                       f"the paper reports.")
    return "\n".join(out)


if __name__ == "__main__":
    print(dryrun_section())
    print()
    print(roofline_section())
    print()
    print(repro_section())
