#!/usr/bin/env python
"""Static-analysis gate: compiled-HLO invariants + serving-discipline lint.

Runs both analysis planes (DESIGN.md §15) and exits nonzero on any
violation — CI runs this (the ``static-analysis`` job) before the bench
jobs, so an invariant regression fails fast with a named rule instead of
showing up as an unexplained bench slowdown three jobs later.

  plane "hlo"   builds small live engines across the KV matrix
                ({bf16, INT8} x {contiguous, paged} + speculative),
                lowers every hot path that carries a
                ``declare_invariants`` spec, and walks the optimized HLO:
                no f32 round-trip on bf16 cache stores (§12), donated
                pools actually aliased, host-sync budget honored,
                retrace count within the window-bucketing bound.
  plane "ast"   lints ``src/repro/serving/*.py`` + ``scripts/
                check_bench.py`` against the five repo-specific rules in
                ``repro.analysis.astlint``.

Usage:
    PYTHONPATH=src python scripts/check_static.py            # both planes
    PYTHONPATH=src python scripts/check_static.py --plane ast
    PYTHONPATH=src python scripts/check_static.py --plane hlo
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.analysis import render                        # noqa: E402
from repro.analysis import astlint                       # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plane", choices=("hlo", "ast", "all"), default="all")
    ap.add_argument("--root", default=str(
        pathlib.Path(__file__).resolve().parent.parent))
    args = ap.parse_args()

    violations = []
    if args.plane in ("ast", "all"):
        print(f"[ast] linting {args.root}")
        violations += astlint.lint_tree(args.root)
    if args.plane in ("hlo", "all"):
        # imported lazily: the AST plane must stay runnable on a box
        # without a working jax device
        from repro.analysis import hlo_checks
        violations += hlo_checks.run_hlo_plane(log=print)

    print(render(violations))
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
