"""Warn-only diff of a fresh bench run against the committed baseline.

  python scripts/bench_diff.py BENCH_pr.json /tmp/baseline.json

CI generates BENCH_pr.json in the workspace (overwriting the checked-out
copy), extracts the committed copy via ``git show HEAD:BENCH_pr.json``, and
runs this to surface regressions as GitHub warning annotations — NEVER as
failures. The hard perf gates live in ``check_bench.py``; this script is
the trajectory view: it flags serving variants whose tokens/s dropped more
than TOK_S_WARN and rows whose us_per_call grew more than US_WARN relative
to the committed numbers, so a PR that legally passes the gates but quietly
costs 20% still shows up in the checks tab. Robustness trajectory rides
along: growing chaos fault counts, leaked pages, a worsening survivor-p95
ratio, or new deadline expiries in the feasibility storm are annotated the
same warn-only way. Exit code is always 0 (a
missing or unparseable baseline just means there is nothing to diff —
first PR after the bench landed, or a force-push history edit).
"""
from __future__ import annotations

import json
import pathlib
import sys

TOK_S_WARN = 0.85   # serving variant tokens/s below this fraction of base
US_WARN = 1.25      # row us_per_call above this multiple of base
HIST_DRIFT_WARN = 0.25   # fraction of bucket mass that moved between the
                         # baseline and new latency/TTFT histograms (L1/2)


def _hist_drift(n_h, b_h):
    """Shape drift between two BENCH histogram dicts ({"le", "counts",
    ...}, the telemetry.Histogram.to_dict form): half the L1 distance
    between normalized bucket masses — 0 when shapes match, 1 when all
    mass moved. None when either side is missing, empty, or the bucket
    edges differ (not comparable)."""
    if not (isinstance(n_h, dict) and isinstance(b_h, dict)):
        return None
    if n_h.get("le") != b_h.get("le"):
        return None
    nc, bc = n_h.get("counts"), b_h.get("counts")
    if not (isinstance(nc, list) and isinstance(bc, list)
            and len(nc) == len(bc)):
        return None
    nt, bt = sum(nc), sum(bc)
    if not nt or not bt:
        return None
    return 0.5 * sum(abs(a / nt - b / bt) for a, b in zip(nc, bc))


def _load(path: str):
    try:
        return json.loads(pathlib.Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read {path} ({e}); nothing to diff")
        return None


def main(argv) -> int:
    if len(argv) != 2:
        print("usage: bench_diff.py NEW.json BASELINE.json")
        return 0
    new, base = _load(argv[0]), _load(argv[1])
    if not new or not base:
        return 0
    warned = 0

    nv = (new.get("serving") or {}).get("variants") or {}
    bv = (base.get("serving") or {}).get("variants") or {}
    # one-sided variants are population changes, not regressions: annotate
    # them (notice-level — they are usually the PR's whole point) instead
    # of KeyError-ing or silently skipping them in the intersection walks
    for name in sorted(set(nv) - set(bv)):
        print(f"::notice::serving/{name}: new variant (no baseline to "
              f"diff against; first committed numbers land with this PR)")
    for name in sorted(set(bv) - set(nv)):
        print(f"::notice::serving/{name}: variant removed (present in "
              f"baseline, absent from this run — intentional retirement "
              f"or a bench that silently stopped running?)")
    for name in sorted(set(nv) & set(bv)):
        n_tok = nv[name].get("tokens_per_s")
        b_tok = bv[name].get("tokens_per_s")
        if not (isinstance(n_tok, (int, float))
                and isinstance(b_tok, (int, float)) and b_tok > 0):
            continue
        frac = n_tok / b_tok
        if frac < TOK_S_WARN:
            print(f"::warning::serving/{name} tokens/s regressed: "
                  f"{b_tok:.1f} -> {n_tok:.1f} ({frac:.2f}x baseline)")
            warned += 1
        # distribution-shape trajectory: percentile gates can hold while
        # the whole latency/TTFT distribution quietly shifts buckets
        for key in ("latency_hist", "ttft_hist"):
            drift = _hist_drift(nv[name].get(key), bv[name].get(key))
            if drift is not None and drift > HIST_DRIFT_WARN:
                print(f"::notice::serving/{name} {key} shape drifted: "
                      f"{drift:.0%} of bucket mass moved vs baseline "
                      f"(> {HIST_DRIFT_WARN:.0%}; same log-spaced edges "
                      f"— compare the two runs' histograms)")

    # http variants carry trajectory signals beyond raw tokens/s: transport
    # efficiency (goodput as a fraction of the same engine in-process) and
    # below-knee overload behavior (shed rate / deadline violations)
    if "http_stream" in nv and "http_stream" in bv:
        n_r = nv["http_stream"].get("goodput_ratio")
        b_r = bv["http_stream"].get("goodput_ratio")
        if (isinstance(n_r, (int, float)) and isinstance(b_r, (int, float))
                and n_r < b_r - 0.05):
            print(f"::warning::serving/http_stream transport efficiency "
                  f"dropped: goodput {b_r:.2f}x -> {n_r:.2f}x of the "
                  f"in-process engine")
            warned += 1
    if "http_overload" in nv and "http_overload" in bv:
        def _low(v):
            sweep = [p for p in v.get("sweep") or []
                     if isinstance(p.get("offered_rps"), (int, float))]
            return min(sweep, key=lambda p: p["offered_rps"]) if sweep \
                else None
        n_low, b_low = _low(nv["http_overload"]), _low(bv["http_overload"])
        if n_low and b_low:
            n_s, b_s = n_low.get("shed_rate", 0), b_low.get("shed_rate", 0)
            if n_s > b_s + 0.1:
                print(f"::warning::serving/http_overload below-knee shed "
                      f"rate grew: {b_s:.2f} -> {n_s:.2f} (admission "
                      f"control rejecting load it used to carry)")
                warned += 1
            n_v = n_low.get("deadline_violations", 0)
            if n_v and not b_low.get("deadline_violations", 0):
                print(f"::warning::serving/http_overload below-knee point "
                      f"now violates {n_v} deadline(s); baseline had none")
                warned += 1

    # fault-tolerance trajectory: more faults than the injectors account
    # for, a worsening survivor p95, leaked pages, or new deadline expiries
    # in the feasibility storm all mean robustness drifted even if the
    # hard chaos gates still pass
    if "chaos" in nv and "chaos" in bv:
        n_c, b_c = nv["chaos"], bv["chaos"]
        n_f, b_f = n_c.get("faults", 0), b_c.get("faults", 0)
        if isinstance(n_f, (int, float)) and n_f > b_f:
            print(f"::warning::serving/chaos fault count grew: {b_f} -> "
                  f"{n_f} (same injector schedule — extra faults are "
                  f"collateral damage, not injections)")
            warned += 1
        if n_c.get("leaked_pages", 0):
            print(f"::warning::serving/chaos leaked "
                  f"{n_c['leaked_pages']} page(s); baseline leaked "
                  f"{b_c.get('leaked_pages', 0)}")
            warned += 1
        n_r, b_r = n_c.get("p95_ratio"), b_c.get("p95_ratio")
        if (isinstance(n_r, (int, float)) and isinstance(b_r, (int, float))
                and n_r > b_r + 0.25):
            print(f"::warning::serving/chaos survivor p95 worsened vs "
                  f"fault-free: {b_r:.2f}x -> {n_r:.2f}x (fault handling "
                  f"is costing the surviving batch more)")
            warned += 1
    if "admission_feasible" in nv and "admission_feasible" in bv:
        n_e = nv["admission_feasible"].get("expired", 0)
        if n_e and not bv["admission_feasible"].get("expired", 0):
            print(f"::warning::serving/admission_feasible now expires "
                  f"{n_e} admitted deadline(s); baseline expired none — "
                  f"the feasibility predictor is admitting work it "
                  f"cannot serve")
            warned += 1

    n_rows = {r["name"]: r for r in new.get("rows") or []
              if isinstance(r.get("us_per_call"), (int, float))}
    b_rows = {r["name"]: r for r in base.get("rows") or []
              if isinstance(r.get("us_per_call"), (int, float))}
    for name in sorted(set(n_rows) - set(b_rows)):
        print(f"::notice::{name}: new row (no baseline us_per_call)")
    for name in sorted(set(b_rows) - set(n_rows)):
        print(f"::notice::{name}: row removed (was "
              f"{b_rows[name]['us_per_call']:.1f}us in baseline)")
    for name in sorted(set(n_rows) & set(b_rows)):
        b_us = b_rows[name]["us_per_call"]
        n_us = n_rows[name]["us_per_call"]
        if b_us > 0 and n_us / b_us > US_WARN:
            print(f"::warning::{name} slowed: {b_us:.1f}us -> {n_us:.1f}us "
                  f"({n_us / b_us:.2f}x baseline)")
            warned += 1

    if warned:
        print(f"bench_diff: {warned} regression warning(s) vs committed "
              f"baseline (informational; hard gates are check_bench.py)")
    else:
        print(f"bench_diff: no regressions vs baseline "
              f"({len(set(n_rows) & set(b_rows))} comparable rows, "
              f"{len(set(nv) & set(bv))} serving variants)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
