"""The paper's own experiment, reduced: HQP vs Q8-only vs P50-only on
MobileNetV3-Small (Table I analogue), ~3-5 minutes on CPU.

  PYTHONPATH=src python examples/hqp_cnn.py [resnet18|mobilenetv3s]
"""
import sys

sys.path.insert(0, "src")

from repro.repro_exp.cnn_experiment import run_experiment

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "mobilenetv3s"
    table = run_experiment(arch, train_steps=200, n_train=3000, n_val=1000,
                           n_calib=500)
    print("\n=== Table ===")
    for r in table["rows"]:
        print(f"{r['method']:24s} acc={r['accuracy']:.4f} "
              f"drop={r['drop']*100:+.2f}% size-{r['size_reduction']:.0%} "
              f"θ={r['theta']:.0%} compliant={r['compliant']}")
    print("modeled speedups:", {k: round(v, 2)
                                for k, v in table["speedups_modeled"].items()})
