"""Batched serving example with the HQP-compressed model (INT8 weights +
INT8 KV cache) vs the bf16 baseline.

  PYTHONPATH=src python examples/serve_hqp.py [--arch stablelm-1.6b]
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    extra = sys.argv[1:] or []
    print("--- bf16 baseline ---")
    main(["--smoke", "--batch", "4", "--prompt-len", "16",
          "--tokens", "16"] + extra)
    print("--- HQP INT8 ---")
    main(["--smoke", "--batch", "4", "--prompt-len", "16",
          "--tokens", "16", "--hqp"] + extra)
