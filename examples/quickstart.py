"""Quickstart: the full HQP pipeline on a small LM, end to end on CPU.

  1. train a reduced qwen3-family model on a synthetic Markov corpus,
  2. compute the diagonal-Fisher structural sensitivity S (one backward pass),
  3. run Algorithm 1 (conditional iterative pruning, Δ_ax on next-token acc),
  4. INT8 PTQ the maximal sparse model (per-channel, W8A8 execution path),
  5. serve it with an INT8 KV cache and compare size / accuracy.

Runs in ~2-4 minutes on a single CPU:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import pipeline, quantization, sensitivity
from repro.core.pruning import param_bytes
from repro.data.synthetic import SyntheticTokens
from repro.models import lm
from repro.sharding.ctx import default_ctx
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_eval_step, make_train_step


def main():
    arch = "qwen3-0.6b"
    cfg = configs.get_smoke_config(arch)
    ctx = default_ctx()
    print(f"== HQP quickstart on {cfg.name} ==")

    # ---- 1. train ----
    data = SyntheticTokens(cfg.vocab_size, 33, 2048, seed=0, determinism=0.9)
    val = SyntheticTokens(cfg.vocab_size, 33, 512, seed=9, determinism=0.9)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, opt_cfg)
    train = jax.jit(make_train_step(cfg, ctx, opt_cfg), donate_argnums=(0, 1))
    it = data.batches(64, seed=0, epochs=100)
    for step in range(240):
        params, opt, m = train(params, opt,
                               {"tokens": jnp.asarray(next(it)["tokens"])})
        if step % 60 == 0:
            print(f"  step {step:4d} loss={float(m['loss']):.3f}")

    eval_step = jax.jit(make_eval_step(cfg, ctx))
    val_batches = [jnp.asarray(b["tokens"]) for b in val.batches(64)]

    def accuracy(p):
        return float(np.mean([float(eval_step(p, {"tokens": t}))
                              for t in val_batches]))

    a0 = accuracy(params)
    print(f"baseline next-token accuracy: {a0:.3f} "
          f"(chain ceiling {data.best_acc})")

    # ---- 2. Fisher sensitivity (one pass over D_calib) ----
    grad = jax.jit(lambda p, b: jax.grad(
        lambda pp: lm.loss_fn(pp, cfg, b, ctx, with_aux=False)[0])(p))
    calib = [{"tokens": jnp.asarray(b["tokens"])}
             for b in data.batches(64)][:4]
    sq, _ = sensitivity.fisher_diag(grad, params, calib)

    # ---- 3. Algorithm 1 ----
    specs = sensitivity.lm_prune_groups(cfg)
    hqp = pipeline.HQPConfig(delta_ax=0.015, step_frac=0.05, max_steps=20)
    res = pipeline.conditional_prune(params, specs, sq, accuracy, hqp,
                                     a_baseline=a0)
    print(f"pruned θ={res.theta:.0%} (acc {res.a_final:.3f}, "
          f"drop {a0 - res.a_final:+.4f} <= {hqp.delta_ax})")

    # ---- 4. INT8 PTQ ----
    params_int8 = quantization.quantize_lm_params(res.params_sparse)
    a_hqp = accuracy(params_int8)
    print(f"HQP (prune+INT8): acc={a_hqp:.3f} drop={a0 - a_hqp:+.4f} "
          f"size {param_bytes(params)/1e6:.1f}MB -> "
          f"{param_bytes(quantization.quantize_lm_params(res.params_compact))/1e6:.1f}MB")

    # ---- 5. serve with INT8 KV cache ----
    sctx = dataclasses.replace(ctx, quantized_kv=True)
    state = lm.init_decode_state(cfg, 2, 64, sctx)
    prompt = jnp.asarray(val.seqs[:2, :16])
    logits, state = lm.decode_step(params_int8, cfg, state, prompt, sctx)
    toks = []
    tok = jnp.argmax(logits[:, -1:], -1)
    for _ in range(8):
        logits, state = lm.decode_step(params_int8, cfg, state, tok, sctx)
        tok = jnp.argmax(logits[:, -1:], -1)
        toks.append(np.asarray(tok)[:, 0])
    print("decoded continuation:", np.stack(toks, 1).tolist())
    print("== done ==")


if __name__ == "__main__":
    main()
