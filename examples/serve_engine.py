"""Continuous-batching engine demo: overlapping requests against an HQP
artifact, with per-request latency stats and a token-identity check against
serial single-request decode.

  PYTHONPATH=src python examples/serve_engine.py [--arch stablelm-1.6b]

Shows the Engine API directly (launch/serve.py --engine wraps the same thing
behind trace replay): submit staggered requests, step the engine, read
per-request results.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.compress import compress
from repro.models import lm
from repro.serving import Engine, Request, SchedulerConfig, serial_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--n-requests", type=int, default=5)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, log=lambda s: None)    # PTQ-only INT8 artifact
    print(art.manifest.summary())

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 8 + (3 * i) % 9).tolist()
               for i in range(args.n_requests)]
    reqs = [Request(prompt=p, max_new_tokens=args.tokens) for p in prompts]

    eng = Engine(art.params, cfg, n_slots=3, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=8))
    # requests arrive over time: one new request every 2 engine ticks
    results = eng.run(reqs, arrival_ticks=[2 * i for i in range(len(reqs))])

    for i, res in sorted(results.items()):
        ref = serial_decode(art.params, cfg, prompts[i], args.tokens,
                            max_seq=64)
        tag = "OK " if res.tokens == ref else "MISMATCH"
        print(f"[{tag}] req{i} prompt={res.prompt_len:2d}t "
              f"-> {len(res.tokens)} tokens, ttft {res.ttft_s*1e3:6.1f}ms, "
              f"latency {res.latency_s*1e3:6.1f}ms: {res.tokens[:8]}...")
    print(f"engine ticks: {eng.ticks} "
          f"({eng.stats['prefill_ticks']} prefill / "
          f"{eng.stats['decode_ticks']} decode, "
          f"{eng.stats['decode_slot_steps']} slot-steps, "
          f"{eng.stats['device_steps']} device decode steps in "
          f"{eng.stats['host_syncs']} host syncs)")


if __name__ == "__main__":
    main()
