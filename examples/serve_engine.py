"""Continuous-batching engine demo: overlapping requests against an HQP
artifact, with per-request latency stats and a token-identity check against
serial single-request decode.

  PYTHONPATH=src python examples/serve_engine.py [--arch stablelm-1.6b]
  PYTHONPATH=src python examples/serve_engine.py --spec-k 4     # speculative
  PYTHONPATH=src python examples/serve_engine.py --temperature 0.8 \\
      --top-k 16 --seed 7                                       # sampling

Shows the Engine API directly (launch/serve.py --engine wraps the same thing
behind trace replay): submit staggered requests, step the engine, read
per-request results. Three modes share one code path:

  default          the INT8 HQP artifact serves greedily
  --temperature    seeded temperature/top-k sampling — same seed => same
                   tokens, engine and serial alike (checked below)
  --spec-k K       self-speculative: the artifact DRAFTS K tokens per
                   cycle, its bf16 parent VERIFIES — greedy output is
                   bit-identical to serial bf16 decode, and the stats line
                   reports the acceptance rate
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.compress import compress
from repro.models import lm
from repro.serving import (Engine, Request, SamplingConfig, SchedulerConfig,
                           serial_decode)
from repro.sharding.ctx import default_ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--n-requests", type=int, default=5)
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft length (0 = off)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, log=lambda s: None)    # PTQ-only INT8 artifact
    print(art.manifest.summary())
    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=args.top_k, seed=args.seed)

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 8 + (3 * i) % 9).tolist()
               for i in range(args.n_requests)]
    reqs = [Request(prompt=p, max_new_tokens=args.tokens) for p in prompts]

    if args.spec_k:
        # artifact = drafter, bf16 parent = verifier: output must be
        # bit-identical to serial decode of the PARENT (greedy mode)
        ctx_q = dataclasses.replace(default_ctx(), quantized_kv=True)
        eng = Engine(params, cfg, n_slots=3, max_seq=64,
                     sched=SchedulerConfig(prefill_chunk=8),
                     sampling=sampling, draft_params=art.params,
                     spec_k=args.spec_k, spec_cycles=2, draft_ctx=ctx_q,
                     draft_manifest=art.manifest)
        ref_params = params
    else:
        eng = Engine(art.params, cfg, n_slots=3, max_seq=64,
                     sched=SchedulerConfig(prefill_chunk=8),
                     sampling=sampling)
        ref_params = art.params
    # requests arrive over time: one new request every 2 engine ticks
    results = eng.run(reqs, arrival_ticks=[2 * i for i in range(len(reqs))])

    check = sampling.is_greedy or not args.spec_k
    for i, res in sorted(results.items()):
        if check:
            # greedy always verifies; plain-engine sampling verifies too
            # (same seed => same tokens); speculative sampling matches the
            # verifier's distribution, not its sequence
            ref = serial_decode(ref_params, cfg, prompts[i], args.tokens,
                                max_seq=64, sampling=sampling)
            tag = "OK " if res.tokens == ref else "MISMATCH"
        else:
            tag = "SPL"
        print(f"[{tag}] req{i} prompt={res.prompt_len:2d}t "
              f"-> {len(res.tokens)} tokens, ttft {res.ttft_s*1e3:6.1f}ms, "
              f"latency {res.latency_s*1e3:6.1f}ms: {res.tokens[:8]}...")
    accept = (eng.stats["accepted_tokens"]
              / max(eng.stats["drafted_tokens"], 1))
    print(f"engine ticks: {eng.ticks} "
          f"({eng.stats['prefill_ticks']} prefill / "
          f"{eng.stats['decode_ticks']} decode, "
          f"{eng.stats['decode_slot_steps']} slot-steps, "
          f"{eng.stats['device_steps']} device decode steps in "
          f"{eng.stats['host_syncs']} host syncs, "
          f"{eng.stats['accepted_tokens']}/{eng.stats['drafted_tokens']} "
          f"drafts accepted = {accept:.2f})")


if __name__ == "__main__":
    main()
