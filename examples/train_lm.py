"""End-to-end training driver example: trains a reduced model for a few
hundred steps with checkpointing + resume (kill it mid-run and rerun: it
resumes from the last committed checkpoint).

  PYTHONPATH=src python examples/train_lm.py [--arch granite-3-8b]
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:] or []
    main(["--smoke", "--steps", "200", "--batch", "8", "--seq", "64",
          "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "50",
          "--eval-every", "100"] + args)
