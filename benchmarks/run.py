"""Benchmark harness — one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's headline
metric). Heavy artifacts (the CNN HQP experiment, the dry-run roofline cells)
are read from experiments/ when present; otherwise a reduced inline version
runs so this module is always executable on a bare CPU container.

  Table I   (MobileNetV3 HQP vs Q8 vs P50)  -> bench_table1_mobilenetv3
  Table II  (ResNet-18 HQP vs Q8)           -> bench_table2_resnet18
  SIII-C    (C_HQP vs C_QAT complexity)     -> bench_complexity_analysis
  SV-C      (layer-wise non-uniform theta)  -> bench_layerwise_sparsity
  SV-E      (energy ratio == speedup)       -> bench_energy
  Fig. 2/3 analogue (LM fleet)              -> bench_lm_hqp_serving
  continuous-batching engine                -> bench_serving
  self-speculative (HQP drafts, bf16 checks)-> bench_speculative
  paged KV + shared-prefix reuse            -> bench_paged
  HTTP/SSE front door + overload sweep      -> bench_http
  fault injection + feasibility admission   -> bench_chaos
  decode attention (windowed vs full)       -> bench_decode_attention
  prefill attention (kernel vs einsum)      -> bench_prefill_attention
  kernels                                   -> bench_kernels
  SRoofline                                 -> bench_roofline_table

CLI:
  python benchmarks/run.py                          # everything, CSV rows
  python benchmarks/run.py --only serving,kernels \
      --json BENCH_pr.json                          # CI perf-trajectory mode

``bench_serving`` additionally writes BENCH_serving.json (tokens/s + latency
percentiles per variant); ``--json`` wraps all emitted rows plus the serving
payload into one schema-tagged file CI validates and uploads.
"""
from __future__ import annotations

import argparse
import asyncio
import gc
import json
import pathlib
import time
from typing import List, Tuple

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
REPRO_DIR = ROOT / "experiments" / "repro"
DRYRUN_DIR = ROOT / "experiments" / "dryrun"

BENCH_SCHEMA = "repro-bench/v1"
SERVING_SCHEMA = "repro-bench-serving/v1"

Row = Tuple[str, float, str]

# last bench_serving payload, picked up by --json (benches keep the uniform
# "returns rows" signature)
_LAST_SERVING: dict = {}


def _timed_min(fn, args, reps: int) -> float:
    """Warmup + min-of-reps timing for the gated attention benches.

    Min, not median: the flatness/ratio gates drive CI, and on shared
    runners scheduler noise only ever ADDS time — the minimum is the stable
    estimate of the true cost."""
    import jax
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def _load_or_run_cnn(arch: str) -> dict:
    f = REPRO_DIR / f"{arch}.json"
    if f.exists():
        return json.loads(f.read_text())
    from repro.repro_exp.cnn_experiment import run_experiment
    table = run_experiment(arch, train_steps=150, n_train=2000, n_val=800,
                           n_calib=400, log=lambda s: None)
    REPRO_DIR.mkdir(parents=True, exist_ok=True)
    f.write_text(json.dumps(table, indent=1))
    return table


def _cnn_rows(table: dict, tag: str) -> List[Row]:
    rows = []
    for r in table["rows"]:
        sp_model = table["speedups_modeled"][r["method"]]
        name = r["method"].replace(" ", "_").replace("(", "").replace(")", "")
        rows.append((
            f"{tag}/{name}",
            r["measured_ms"] * 1000,
            f"speedup={sp_model:.2f}x size_red={r['size_reduction']:.0%} "
            f"drop={r['drop']*100:.2f}pct theta={r['theta']:.0%} "
            f"compliant={r['compliant']}"))
    return rows


def bench_table1_mobilenetv3() -> List[Row]:
    return _cnn_rows(_load_or_run_cnn("mobilenetv3s"), "table1_mbv3")


def bench_table2_resnet18() -> List[Row]:
    return _cnn_rows(_load_or_run_cnn("resnet18"), "table2_resnet18")


def bench_complexity_analysis() -> List[Row]:
    """C_HQP = N_calib*C_grad + T_prune*N_val*C_inf  vs  C_QAT (SIII-C)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_cnn_config
    from repro.models import cnn
    from repro.repro_exp.cnn_experiment import ce_loss
    cfg = dataclasses.replace(get_cnn_config("mobilenetv3s"), width_mult=0.5)
    v = cnn.cnn_init(jax.random.PRNGKey(0), cfg)
    x = {"image": jnp.zeros((100, 32, 32, 3)),
         "label": jnp.zeros((100,), jnp.int32)}
    grad = jax.jit(jax.grad(lambda p, b: ce_loss(
        cfg, {"params": p, "stats": v["stats"]}, b)[0]))
    inf = jax.jit(lambda vv, b: cnn.cnn_apply(cfg, vv, b["image"])[0])
    grad(v["params"], x)
    inf(v, x)

    def t(f, *a):
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*a))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    c_grad, c_inf = t(grad, v["params"], x), t(inf, v, x)
    n_calib, n_val, n_train, t_prune, epochs = 5000, 5000, 1_281_167, 45, 5
    c_hqp = n_calib / 100 * c_grad + t_prune * (n_val / 100) * c_inf
    c_qat = epochs * n_train / 100 * c_grad
    return [
        ("complexity/C_grad_per_100", c_grad * 1e6, "forward-backward"),
        ("complexity/C_inf_per_100", c_inf * 1e6, "inference"),
        ("complexity/C_HQP", c_hqp * 1e6, f"{c_hqp:.0f}s-equivalent"),
        ("complexity/C_QAT", c_qat * 1e6,
         f"QAT/HQP={c_qat / c_hqp:.0f}x (paper: orders of magnitude)"),
    ]


def bench_layerwise_sparsity() -> List[Row]:
    """SV-C: non-uniform theta across depth."""
    table = _load_or_run_cnn("mobilenetv3s")
    fam = table["hqp_sparsity_by_family"]
    # manifest format stores θ floats; pre-artifact caches stored info dicts
    thetas = {k: (v["theta"] if isinstance(v, dict) else v)
              for k, v in fam.items()}
    if not thetas:
        return [("layerwise/none", 0.0, "no families")]
    mx = max(thetas, key=thetas.get)
    mn = min(thetas, key=thetas.get)
    return [
        ("layerwise/max_theta", 0.0, f"{mx}={thetas[mx]:.0%}"),
        ("layerwise/min_theta", 0.0, f"{mn}={thetas[mn]:.0%}"),
        ("layerwise/spread", 0.0,
         f"nonuniform={thetas[mx] - thetas[mn]:.0%}"),
    ]


def bench_energy() -> List[Row]:
    """SV-E: E = P*L  =>  energy ratio == speedup (identity check)."""
    table = _load_or_run_cnn("mobilenetv3s")
    sp = table["speedups_modeled"]["Proposed HQP"]
    return [("energy/ratio_equals_speedup", 0.0,
             f"E_FP32/E_HQP={sp:.2f}x==speedup")]


def bench_lm_hqp_serving() -> List[Row]:
    """LM-fleet analogue of Tables I/II: decode us/token + size reduction,
    with the INT8 row served from the typed ``compress()`` artifact."""
    import dataclasses as dc
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.compress import compress
    from repro.core.pipeline import HQPConfig
    from repro.core.pruning import param_bytes
    from repro.models import lm
    from repro.sharding.ctx import default_ctx
    cfg = configs.get_smoke_config("granite-3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, hqp=HQPConfig(weight_granularity="channel"),
                   log=lambda s: None)
    rows = [("lm_serving/manifest", 0.0,
             f"bytes={art.manifest.bytes_before}->{art.manifest.bytes_after} "
             f"qfrac={art.manifest.quantized_fraction:.2f} "
             f"theta={art.manifest.theta:.2f}")]
    for name, p, qkv in [("bf16", params, False),
                         ("hqp_int8", art.params, True)]:
        ctx = dc.replace(default_ctx(), quantized_kv=qkv)
        state = lm.init_decode_state(cfg, 4, 64, ctx,
                                     params=p if qkv else None)
        tok = jnp.zeros((4, 1), jnp.int32)
        step = jax.jit(lambda pp, s, t: lm.decode_step(pp, cfg, s, t, ctx))
        logits, state = step(p, state, tok)
        jax.block_until_ready(logits)
        ts = []
        for _ in range(10):
            t0 = time.perf_counter()
            logits, state = step(p, state, tok)
            jax.block_until_ready(logits)
            ts.append(time.perf_counter() - t0)
        rows.append((f"lm_serving/{name}", float(np.median(ts)) * 1e6,
                     f"size={param_bytes(p)/1e6:.1f}MB"))
    return rows


def bench_serving(out_path: str = "BENCH_serving.json") -> List[Row]:
    """Continuous-batching engine throughput + latency percentiles, bf16 vs
    the INT8 HQP artifact — the serving-regime numbers CI tracks per PR.

    The ``bf16_sync1`` variant pins ``decode_steps=1`` (the PR-2 per-token
    host-sync behavior) against the default multi-step device decode loop, so
    the host-sync amortization shows up as a tokens/s delta in the same file;
    every variant also records ``host_syncs``/``device_steps`` so the win is
    observable, not inferred."""
    import dataclasses as dc
    import jax
    from repro import configs
    from repro.compress import compress
    from repro.core.pruning import param_bytes
    from repro.models import lm
    from repro.serving import (Engine, Request, SchedulerConfig,
                               summarize_results)
    from repro.sharding.ctx import default_ctx

    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, log=lambda s: None)
    rng = np.random.RandomState(0)
    n_req, new_tok, n_slots, chunk, dsteps = 8, 16, 4, 8, 4
    prompts = [rng.randint(0, cfg.vocab_size, 8 + (5 * i) % 13).tolist()
               for i in range(n_req)]

    payload = _serving_payload(cfg, n_req, n_slots, chunk, new_tok, dsteps)
    rows: List[Row] = []
    for name, p, qkv, ds in [("bf16", params, False, dsteps),
                             ("bf16_sync1", params, False, 1),
                             ("hqp_int8", art.params, True, dsteps)]:
        ctx = dc.replace(default_ctx(), quantized_kv=qkv)
        eng = Engine(p, cfg, ctx=ctx, n_slots=n_slots, max_seq=64,
                     sched=SchedulerConfig(prefill_chunk=chunk,
                                           decode_steps=ds))
        reqs = [Request(prompt=pr, max_new_tokens=new_tok) for pr in prompts]
        arrivals = [2 * i for i in range(n_req)]
        results, wall = _timed_engine_run(eng, reqs, arrivals)
        v = {
            **summarize_results(results, wall),
            "param_bytes": int(param_bytes(p)),
            "decode_ticks": eng.stats["decode_ticks"],
            "prefill_ticks": eng.stats["prefill_ticks"],
            "decode_steps": ds,
            "host_syncs": eng.stats["host_syncs"],
            "device_steps": eng.stats["device_steps"],
        }
        if name == "hqp_int8":
            v["artifact_bytes"] = art.manifest.bytes_after
            v["bytes_before"] = art.manifest.bytes_before
        payload["variants"][name] = v
        payload["expected_variants"].append(name)
        rows.append((f"serving/{name}", wall / max(v["out_tokens"], 1) * 1e6,
                     f"tok_s={v['tokens_per_s']:.1f} "
                     f"p50={v['latency_p50_ms']:.0f}ms "
                     f"p95={v['latency_p95_ms']:.0f}ms "
                     f"syncs={v['host_syncs']} dsteps={v['device_steps']} "
                     f"bytes={v['param_bytes']}"))

    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1))
    return rows


def _serving_payload(cfg, n_req, n_slots, chunk, new_tok, dsteps) -> dict:
    """The shared BENCH_serving.json payload: ``bench_serving`` and
    ``bench_speculative`` both merge their variants into ``_LAST_SERVING``
    (and re-write the file), so one schema-tagged document carries the full
    bf16 / int8 / speculative comparison regardless of which benches a
    ``--only`` subset selected. ``expected_variants`` records every variant
    a bench in this process INTENDED to produce — ``check_bench`` fails
    with a named-variant message if one is missing from the final file."""
    global _LAST_SERVING
    if not _LAST_SERVING:
        _LAST_SERVING = {"schema": SERVING_SCHEMA, "arch": cfg.name,
                         "n_requests": n_req, "n_slots": n_slots,
                         "prefill_chunk": chunk, "max_new_tokens": new_tok,
                         "decode_steps": dsteps, "variants": {},
                         "expected_variants": []}
    _LAST_SERVING.setdefault("expected_variants", [])
    return _LAST_SERVING


def _timed_engine_run(eng, reqs, arrivals, best_of: int = 2):
    """Warmup run (compiles every tail-chunk shape and window bucket), then
    ``best_of`` timed runs keeping the fastest — shared-runner noise only
    ever ADDS time, and the serving gates compare variants against each
    other. Returns (results, wall_s) from the fastest run; ``eng.stats``
    holds exactly one run's counters (zeroed before each timed run)."""
    eng.run(reqs, arrival_ticks=arrivals)
    best = None
    for _ in range(best_of):
        for k in eng.stats:
            eng.stats[k] = 0
        t0 = time.perf_counter()
        results = eng.run(reqs, arrival_ticks=arrivals)
        wall = time.perf_counter() - t0
        if best is None or wall < best[1]:
            best = (results, wall)
    return best


def bench_speculative(out_path: str = "BENCH_serving.json") -> List[Row]:
    """Self-speculative serving (HQP artifact drafts, bf16 parent verifies)
    vs the bf16 ``decode_steps=4`` baseline it must beat, CI-gated by
    ``check_bench``:

      * ``acceptance_rate`` (accepted drafts / drafted tokens, straight
        from ``Engine.stats``) must clear the 0.7 floor — HQP's Δacc bound
        is what makes the compressed artifact a high-acceptance drafter,
        so acceptance IS the quality-vs-speed headline (Ps&Qs: quantization
        as a latency tool);
      * speculative tokens/s must beat the ``spec_baseline`` variant —
        greedy speculative output is bit-identical to serial bf16, so that
        delta is free wall-clock, not a quality trade.

    The workload is SINGLE-STREAM and DECODE-HEAVY (one slot, 48 generated
    tokens per request) — the paper's ultra-low-latency edge regime, and
    speculation's: at batch 1 a multi-position verify pass costs about one
    decode step (op overhead dominates, measured flat in Sq), so k drafts
    + 1 verify buy up to k+1 tokens for ~k+1 invocation-equivalents of the
    CHEAPER drafter, and the per-request p50 latency drops ~2x. (At batch
    4 the verify pass scales with Sq on CPU and the advantage shrinks to
    ~parity — the batched numbers stay visible in ``bench_serving``.)
    Fairness guards: the baseline runs the SAME prompts/arrivals/slots/
    chunking under the default ``decode_steps=4`` scan, and both engines
    are timed in interleaved passes (min per engine) so machine drift
    during the bench cannot bias the ratio — the same discipline as
    ``bench_prefill_attention``."""
    import dataclasses as dc
    import jax
    from repro import configs
    from repro.compress import compress
    from repro.core.pruning import param_bytes
    from repro.models import lm
    from repro.serving import (Engine, Request, SchedulerConfig,
                               summarize_results)
    from repro.sharding.ctx import default_ctx

    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    art = compress(params, cfg, log=lambda s: None)
    rng = np.random.RandomState(0)
    n_req, new_tok, n_slots, chunk = 6, 48, 1, 16
    max_seq, dsteps, spec_k, spec_cycles = 128, 4, 4, 4
    prompts = [rng.randint(0, cfg.vocab_size, 8 + (5 * i) % 13).tolist()
               for i in range(n_req)]
    reqs = [Request(prompt=pr, max_new_tokens=new_tok) for pr in prompts]
    arrivals = [0] * n_req

    payload = _serving_payload(cfg, n_req, n_slots, chunk, new_tok, dsteps)
    rows: List[Row] = []

    ctx_q = dc.replace(default_ctx(), quantized_kv=True)
    base_eng = Engine(params, cfg, ctx=default_ctx(), n_slots=n_slots,
                      max_seq=max_seq,
                      sched=SchedulerConfig(prefill_chunk=chunk,
                                            decode_steps=dsteps))
    spec_eng = Engine(params, cfg, ctx=default_ctx(), n_slots=n_slots,
                      max_seq=max_seq,
                      sched=SchedulerConfig(prefill_chunk=chunk,
                                            decode_steps=dsteps),
                      draft_params=art.params, spec_k=spec_k,
                      spec_cycles=spec_cycles, draft_ctx=ctx_q,
                      draft_manifest=art.manifest)
    best = {}
    for name, eng in (("base", base_eng), ("spec", spec_eng)):
        eng.run(reqs, arrival_ticks=arrivals)      # warmup: compile all
    for _ in range(3):                             # interleaved timed passes
        for name, eng in (("base", base_eng), ("spec", spec_eng)):
            for k in eng.stats:
                eng.stats[k] = 0
            t0 = time.perf_counter()
            results = eng.run(reqs, arrival_ticks=arrivals)
            wall = time.perf_counter() - t0
            if name not in best or wall < best[name][1]:
                best[name] = (results, wall, dict(eng.stats))

    base_res, base_wall, base_stats = best["base"]
    base_sum = summarize_results(base_res, base_wall)
    results, wall, st = best["spec"]
    accept = st["accepted_tokens"] / max(st["drafted_tokens"], 1)
    v = {
        **summarize_results(results, wall),
        "param_bytes": int(param_bytes(params)),
        "artifact_bytes": art.manifest.bytes_after,
        "spec_k": spec_k,
        "spec_cycles": spec_cycles,
        "max_new_tokens": new_tok,
        "decode_ticks": st["decode_ticks"],
        "prefill_ticks": st["prefill_ticks"],
        "host_syncs": st["host_syncs"],
        "device_steps": st["device_steps"],
        "drafted_tokens": st["drafted_tokens"],
        "accepted_tokens": st["accepted_tokens"],
        "acceptance_rate": accept,
        "baseline_tokens_per_s": base_sum["tokens_per_s"],
    }
    v["speedup_vs_bf16"] = (v["tokens_per_s"]
                            / max(base_sum["tokens_per_s"], 1e-9))
    payload["variants"]["speculative"] = v
    # the baseline lives as its own variant: same workload, so the gate
    # compares like with like (bench_serving's "bf16" variant times a
    # different, prefill-heavier workload)
    payload["variants"]["spec_baseline"] = {
        **base_sum,
        "param_bytes": int(param_bytes(params)),
        "decode_steps": dsteps,
        "max_new_tokens": new_tok,
        "host_syncs": base_stats["host_syncs"],
        "device_steps": base_stats["device_steps"],
    }
    payload["expected_variants"] += ["speculative", "spec_baseline"]
    rows.append((
        "serving/speculative", wall / max(v["out_tokens"], 1) * 1e6,
        f"tok_s={v['tokens_per_s']:.1f} accept={accept:.2f} "
        f"speedup_vs_bf16={v['speedup_vs_bf16']:.2f}x k={spec_k} "
        f"c={spec_cycles} syncs={v['host_syncs']} "
        f"drafted={v['drafted_tokens']}"))
    rows.append((
        "serving/spec_baseline",
        base_wall / max(base_sum["out_tokens"], 1) * 1e6,
        f"tok_s={base_sum['tokens_per_s']:.1f} decode_steps={dsteps} "
        f"(same workload as serving/speculative)"))

    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1))
    return rows


def bench_paged(out_path: str = "BENCH_serving.json") -> List[Row]:
    """Paged KV cache vs the contiguous pool it replaced, CI-gated by
    ``check_bench``:

      * ``paged`` vs ``paged_baseline`` — the SAME no-sharing workload
        (distinct random prompts long enough to cross page boundaries) on a
        page_size=16 engine vs a contiguous engine, timed in interleaved
        passes (min per engine, the ``bench_speculative`` discipline so
        machine drift cannot bias the ratio). Paging is pure bookkeeping —
        same kernels, one extra page-table gather — so paged tokens/s must
        stay >= 0.95x contiguous. page_size == window_block here so both
        engines attend IDENTICAL visible windows at every dispatch and the
        ratio isolates pure indirection cost (a page size above the window
        block additionally rounds windows up to whole pages — a real cost,
        but a window-bucketing effect, measured by the attention sweeps,
        not a page-table one; it vanishes as max_seq/page grows while this
        smoke cache is only 2 pages deep). The prefix cache is OFF because
        this variant measures overhead, not reuse.
      * ``paged_shared`` — the repeated-system-prompt workload paging
        exists for: every request shares a 64-token (one-page) head, so
        after the warmup run populates the hash-keyed prefix cache, every
        timed admission maps the shared page copy-free (refcount++) and
        prefills only the tail. Gates: >= 1 prefix hit, prefilled tokens <
        total prompt tokens, and ``kv_bytes_peak`` <= 0.6x the contiguous
        footprint for the same (n_slots, max_seq) — the arena only holds
        pages that are actually mapped, while a contiguous pool pays
        n_slots * max_seq up front."""
    import jax
    from repro import configs
    from repro.core.pruning import param_bytes
    from repro.models import lm
    from repro.serving import (Engine, Request, SchedulerConfig,
                               summarize_results)

    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pbytes = int(param_bytes(params))
    rng = np.random.RandomState(0)
    n_req, new_tok, n_slots, chunk, dsteps = 6, 16, 4, 16, 4
    max_seq, page_size = 128, 64
    parity_ps = 16                    # == window_block: identical windows
    # 96..108-token prompts + 16 generated: every slot's KV spans 7-8
    # pages at the parity page size
    prompts = [rng.randint(0, cfg.vocab_size, 96 + (5 * i) % 13).tolist()
               for i in range(n_req)]
    reqs = [Request(prompt=pr, max_new_tokens=new_tok) for pr in prompts]
    arrivals = [0] * n_req

    payload = _serving_payload(cfg, n_req, n_slots, chunk, new_tok, dsteps)
    rows: List[Row] = []
    sched = SchedulerConfig(prefill_chunk=chunk, decode_steps=dsteps)
    cont_eng = Engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                      sched=sched)
    paged_eng = Engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                       sched=sched, page_size=parity_ps, prefix_cache=False)
    # contiguous engines report their static footprint once at init; the
    # timed-run stat zeroing would lose it (paged engines re-track via the
    # page gauges), so snapshot it here
    cont_kv_bytes = cont_eng.stats["kv_bytes_peak"]
    best = {}
    for name, eng in (("cont", cont_eng), ("paged", paged_eng)):
        eng.run(reqs, arrival_ticks=arrivals)      # warmup: compile all
    for _ in range(3):                             # interleaved timed passes
        for name, eng in (("cont", cont_eng), ("paged", paged_eng)):
            for k in eng.stats:
                eng.stats[k] = 0
            t0 = time.perf_counter()
            results = eng.run(reqs, arrival_ticks=arrivals)
            wall = time.perf_counter() - t0
            if name not in best or wall < best[name][1]:
                best[name] = (results, wall, dict(eng.stats))

    for vname, key in (("paged", "paged"), ("paged_baseline", "cont")):
        results, wall, st = best[key]
        v = {
            **summarize_results(results, wall),
            "param_bytes": pbytes,
            "page_size": parity_ps if key == "paged" else 0,
            "decode_steps": dsteps,
            "host_syncs": st["host_syncs"],
            "device_steps": st["device_steps"],
            "kv_bytes_peak": (st["kv_bytes_peak"] if key == "paged"
                              else cont_kv_bytes),
        }
        if key == "paged":
            v.update(pages_peak=st["pages_peak"], prefix_cache=False)
        payload["variants"][vname] = v
        payload["expected_variants"].append(vname)
        rows.append((f"serving/{vname}",
                     wall / max(v["out_tokens"], 1) * 1e6,
                     f"tok_s={v['tokens_per_s']:.1f} "
                     f"p50={v['latency_p50_ms']:.0f}ms "
                     f"p95={v['latency_p95_ms']:.0f}ms "
                     f"page_size={v['page_size']} "
                     f"kv_peak={v['kv_bytes_peak']}"))
    ratio = (payload["variants"]["paged"]["tokens_per_s"]
             / max(payload["variants"]["paged_baseline"]["tokens_per_s"],
                   1e-9))
    rows[-2] = (rows[-2][0], rows[-2][1],
                rows[-2][2] + f" vs_contiguous={ratio:.2f}x")

    # --- shared-prefix workload: one 64-token system prompt, distinct tails
    head = rng.randint(0, cfg.vocab_size, page_size).tolist()
    sh_reqs = [Request(prompt=head
                       + rng.randint(0, cfg.vocab_size, 8 + (3 * i) % 9)
                       .tolist(),
                       max_new_tokens=new_tok) for i in range(n_req)]
    sh_max_seq = 192
    eng = Engine(params, cfg, n_slots=n_slots, max_seq=sh_max_seq,
                 sched=sched, page_size=page_size)
    results, wall = _timed_engine_run(eng, sh_reqs, [0] * n_req)
    st = eng.stats
    prompt_tokens = sum(len(r.prompt) for r in sh_reqs)
    # what a contiguous pool would pin for the same slots/capacity
    contiguous_bytes = eng._kv_page_bytes * n_slots * eng.max_pages
    v = {
        **summarize_results(results, wall),
        "param_bytes": pbytes,
        "page_size": page_size,
        "max_seq": sh_max_seq,
        "prefix_hits": st["prefix_hits"],
        "prefix_hit_tokens": st["prefix_hit_tokens"],
        "bytes_saved": st["bytes_saved"],
        "cow_copies": st["cow_copies"],
        "prefill_tokens": st["prefill_tokens"],
        "prompt_tokens": prompt_tokens,
        "pages_peak": st["pages_peak"],
        "kv_bytes_peak": st["kv_bytes_peak"],
        "contiguous_kv_bytes": contiguous_bytes,
    }
    payload["variants"]["paged_shared"] = v
    payload["expected_variants"].append("paged_shared")
    rows.append((
        "serving/paged_shared", wall / max(v["out_tokens"], 1) * 1e6,
        f"tok_s={v['tokens_per_s']:.1f} hits={v['prefix_hits']} "
        f"prefilled={v['prefill_tokens']}/{prompt_tokens} "
        f"kv_peak={v['kv_bytes_peak']}/{contiguous_bytes} "
        f"({v['kv_bytes_peak'] / contiguous_bytes:.2f}x contiguous)"))

    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1))
    return rows


async def _sse_request(port: int, body: bytes, delay_s: float = 0.0) -> dict:
    """One streaming client: POST, then read SSE events with a wall-clock
    stamp per event. Returns status + per-token timing raw material.

    The client runs in the server's own event loop, so on this box (one
    CPU core — there is nothing to overlap with anyway) its parse cost
    lands in the measured wall; the hot loop therefore counts token
    frames with C-speed scans over each received segment instead of
    slicing per frame, and JSON-decodes only the final ``done`` frame."""
    if delay_s > 0:
        await asyncio.sleep(delay_s)
    t_send = time.perf_counter()
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: b\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    status = int(head.split(b" ")[1])
    rec = {"status": status, "t_send": t_send, "token_times": [],
           "finish_reason": None, "n_tokens": 0, "t_done": None}
    if status == 200:
        buf = bytearray()
        while rec["t_done"] is None:
            chunk = await reader.read(65536)
            if not chunk:
                break
            t_recv = time.perf_counter()
            buf += chunk
            # process only the complete-frame prefix (a frame may straddle
            # the segment boundary); a burst arriving in one segment shares
            # one stamp — exactly what a real client would observe
            i = buf.rfind(b"\n\n")
            if i < 0:
                continue
            complete = bytes(buf[:i + 2])
            del buf[:i + 2]
            n_tok = complete.count(b"event: token")
            if n_tok:
                rec["token_times"].extend([t_recv] * n_tok)
            j = complete.find(b"event: done")
            if j >= 0:
                frame = complete[j:complete.index(b"\n\n", j)]
                d = json.loads(frame.partition(b"data: ")[2])
                rec["t_done"] = t_recv
                rec["finish_reason"] = d["finish_reason"]
                rec["n_tokens"] = d["n_tokens"]
    else:
        await reader.read()                    # consume the error body
    writer.close()
    return rec


def _run_http_phase(eng, queue_depth, deadline_s, bodies, delays,
                    telemetry_on: bool = True):
    """Fresh Service + front door (ephemeral port) on an already-compiled
    engine; fire one client per body at its delay; drain; return
    (svc.stats, client records, wall_s measured send-to-last-done).
    ``telemetry_on=False`` builds the service without the metrics
    registry/histograms — the control arm of the telemetry-overhead
    gate."""
    from repro.serving.service import HttpFrontDoor, Service, ServiceConfig
    svc = Service(eng, ServiceConfig(queue_depth=queue_depth,
                                     default_deadline_s=deadline_s,
                                     telemetry=telemetry_on))
    door = HttpFrontDoor(svc, host="127.0.0.1", port=0)
    # the previous phase's garbage (dead Service/door/streams, the inproc
    # run's result objects) must not bill its collector pauses to THIS
    # phase's timed window
    gc.collect()

    async def go():
        await door.start()
        try:
            t0 = time.perf_counter()
            recs = await asyncio.gather(
                *[_sse_request(door.port, b, d)
                  for b, d in zip(bodies, delays)])
            wall = time.perf_counter() - t0
            return recs, wall
        finally:
            await door.stop(drain=True)

    recs, wall = asyncio.run(go())
    return dict(svc.stats), recs, wall


def _pct(xs, q, scale=1e3):
    return float(np.percentile(xs, q)) * scale if xs else 0.0


def _client_hist(values_s) -> dict:
    """Client-side seconds -> the same fixed-bucket histogram shape the
    engine reports (telemetry.schema.LATENCY_BUCKETS_S), JSON-ready —
    bench_diff compares these across baselines bucket-wise."""
    from repro.telemetry import Histogram, schema
    h = Histogram("client_s", buckets=schema.LATENCY_BUCKETS_S)
    for v in values_s:
        h.observe(v)
    return h.to_dict()


def bench_http(out_path: str = "BENCH_serving.json") -> List[Row]:
    """The engine behind the real HTTP/SSE front door vs the same engine
    driven in-process — BENCH_serving's traffic benchmark, CI-gated by
    ``check_bench``:

      * ``http_stream`` (CLOSED loop): every client connects at once and
        streams to completion — the same all-at-once workload as the
        in-process ``Engine.run`` timed immediately before ON THE SAME
        ENGINE (same compiled fns, so the delta is pure transport).
        Goodput must stay >= 0.9x in-process tokens/s
        (``goodput_ratio``), with zero sheds and zero deadline
        violations; TTFT and inter-token gap p50/p95/p99 are recorded
        from the CLIENT side of the socket — the numbers a user would
        see, not the engine's view.
      * ``http_overload`` (OPEN loop): uniform-arrival sweep at offered
        rates below/at/above the measured capacity knee
        (``inproc_tokens_per_s / max_new_tokens``) against a deliberately
        shallow admission queue. Below the knee the service must meet
        every deadline (zero violations at zero shed); above it the
        bound must actually engage (sheds > 0) — overload degrades into
        429s, not into blown SLOs. Headline percentile keys summarize
        the LOWEST-rate (below-knee) point; ``sweep`` holds every point.
    """
    import jax
    from repro import configs
    from repro.core.pruning import param_bytes
    from repro.models import lm
    from repro.serving import (Engine, Request, SchedulerConfig,
                               summarize_results)

    import dataclasses

    # 4L/d128 rather than the 2L/d64 smoke config: the transport floor is
    # a fixed ~15-20us/token of syscalls + task wakeups (and this box has
    # ONE core, so none of it overlaps compute), and goodput_ratio is
    # compute/(compute + transport) — measured against a toy model whose
    # decode costs ~130us/token it overstates the transport share ~4x vs
    # any real deployment. Both sides of the ratio run this same engine,
    # so the comparison itself stays apples-to-apples.
    cfg = dataclasses.replace(configs.get_smoke_config("qwen3-0.6b"),
                              n_layers=4, d_model=128, d_ff=256)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    # decode-heavy enough (96 tokens/stream) that the fixed transport
    # transient — 12 TCP connects + the few staggered-admission steps
    # before slots fill — amortizes out of the goodput ratio; decode_steps=8
    # keeps the per-step transport overhead (loop wakeup + one socket
    # write/read per stream) under the step's compute on a one-core box
    n_req, new_tok, n_slots, chunk, dsteps = 12, 96, 4, 8, 8
    prompts = [rng.randint(0, cfg.vocab_size, 8 + (5 * i) % 13).tolist()
               for i in range(n_req)]
    reqs = [Request(prompt=pr, max_new_tokens=new_tok) for pr in prompts]
    bodies = [json.dumps({"prompt": pr, "max_new_tokens": new_tok}).encode()
              for pr in prompts]

    eng = Engine(params, cfg, n_slots=n_slots, max_seq=128,
                 sched=SchedulerConfig(prefill_chunk=chunk,
                                       decode_steps=dsteps))
    # warm both paths once: engine compiles (tail-chunk shapes, window
    # buckets), then the transport (listener, pump thread, client sockets)
    eng.run(reqs, arrival_ticks=[0] * n_req)
    _run_http_phase(eng, queue_depth=n_req, deadline_s=None, bodies=bodies,
                    delays=[0.0] * n_req)
    pbytes = int(param_bytes(params))

    payload = _serving_payload(cfg, n_req, n_slots, chunk, new_tok, dsteps)
    rows: List[Row] = []

    # --- closed loop: all clients at once, queue deep enough to admit all.
    # The in-process baseline, the HTTP phase, and the telemetry-off HTTP
    # control run INTERLEAVED, best-of each, so CPU-clock drift between
    # measurement windows cancels out of goodput_ratio instead of
    # masquerading as overhead. Five iterations, not three: on a one-core
    # box the per-phase wall jitters ~+/-4% (scheduler bursts slow an
    # entire iteration — its inproc AND http phases together), and the
    # best-of floor estimator needs enough samples for both arms' minima
    # to converge or the 0.9x goodput gate flakes on noise alone.
    in_best = best = off_best = None
    pair_ratios = []
    for it in range(7):
        for k in eng.stats:
            eng.stats[k] = 0
        # drain garbage left by earlier benches / the previous iteration
        # OUTSIDE the timed windows: collector pauses hit the
        # allocation-heavy http phases harder than the inproc run, which
        # shows up as a phantom transport cost in goodput_ratio
        gc.collect()
        t0 = time.perf_counter()
        results = eng.run(reqs, arrival_ticks=[0] * n_req)
        iwall = time.perf_counter() - t0
        if in_best is None or iwall < in_best[1]:
            in_best = (results, iwall)
        # the on/off order ALTERNATES per iteration: the box drifts on
        # ~second scales (GC debt from the preceding phase, scheduler
        # bursts), and a fixed order would fold that drift into the
        # overhead ratio as a systematic bias instead of noise
        hwall = owall = None
        for tel_on in ((True, False) if it % 2 == 0 else (False, True)):
            if tel_on:
                st, recs, hwall = _run_http_phase(
                    eng, queue_depth=n_req, deadline_s=None, bodies=bodies,
                    delays=[0.0] * n_req)
                if best is None or hwall < best[2]:
                    best = (st, recs, hwall)
            else:
                _, orecs, owall = _run_http_phase(
                    eng, queue_depth=n_req, deadline_s=None, bodies=bodies,
                    delays=[0.0] * n_req, telemetry_on=False)
                if off_best is None or owall < off_best[1]:
                    off_best = (orecs, owall)
        # the overhead ratio is PAIRED per iteration (on-phase wall vs the
        # adjacent off-phase wall, same token count, both http) and
        # summarized by the median: noise bursts hit adjacent phases
        # together and cancel inside the pair, so a <=3% effect stays
        # resolvable. goodput_ratio stays best-of/best-of instead: its two
        # arms respond to scheduler noise ASYMMETRICALLY (the http arm's
        # thread ping-pong amplifies contention the inproc run shrugs
        # off), so pairing folds that asymmetry in as phantom transport
        # cost, while the minima compare both arms at the box's capable
        # state — which is what a transport-cost floor means
        pair_ratios.append(owall / max(hwall, 1e-9))
    inproc = summarize_results(*in_best)
    st, recs, hwall = best
    orecs, owall = off_best
    off_tokens = sum(r["n_tokens"] for r in orecs
                     if r["finish_reason"] in ("length", "eos"))
    off_goodput = off_tokens / max(owall, 1e-9)
    overhead_ratio = float(np.median(pair_ratios))
    done = [r for r in recs if r["finish_reason"] in ("length", "eos")]
    out_tokens = sum(r["n_tokens"] for r in done)
    ttfts = [r["token_times"][0] - r["t_send"] for r in done
             if r["token_times"]]
    lats = [r["t_done"] - r["t_send"] for r in done]
    gaps = [b - a for r in done
            for a, b in zip(r["token_times"], r["token_times"][1:])]
    goodput = out_tokens / max(hwall, 1e-9)
    v = {
        "n_requests": n_req,
        "out_tokens": out_tokens,
        "tokens_per_s": goodput,
        "latency_p50_ms": _pct(lats, 50), "latency_p95_ms": _pct(lats, 95),
        "latency_p99_ms": _pct(lats, 99),
        "ttft_p50_ms": _pct(ttfts, 50), "ttft_p95_ms": _pct(ttfts, 95),
        "ttft_p99_ms": _pct(ttfts, 99),
        "tok_gap_p50_ms": _pct(gaps, 50), "tok_gap_p95_ms": _pct(gaps, 95),
        "tok_gap_p99_ms": _pct(gaps, 99),
        "param_bytes": pbytes,
        "max_new_tokens": new_tok,
        "inproc_tokens_per_s": inproc["tokens_per_s"],
        "goodput_ratio": goodput / max(inproc["tokens_per_s"], 1e-9),
        "tokens_per_s_telemetry_off": off_goodput,
        "telemetry_overhead_ratio": overhead_ratio,
        "ttft_hist": _client_hist(ttfts),
        "latency_hist": _client_hist(lats),
        "completed": len(done),
        "shed": st["shed"],
        "deadline_violations": st["expired"],
    }
    payload["variants"]["http_stream"] = v
    payload["expected_variants"].append("http_stream")
    rows.append((
        "serving/http_stream", hwall / max(out_tokens, 1) * 1e6,
        f"goodput={goodput:.1f}tok_s ({v['goodput_ratio']:.2f}x inproc) "
        f"ttft_p50={v['ttft_p50_ms']:.1f}ms "
        f"gap_p50={v['tok_gap_p50_ms']:.1f}ms shed={st['shed']} "
        f"telemetry_overhead={v['telemetry_overhead_ratio']:.3f}x"))

    # --- open loop: uniform arrivals swept past the knee, shallow queue
    cap_rps = inproc["tokens_per_s"] / new_tok
    deadline_s = max(1.0, 20 * _pct(lats, 95) / 1e3)
    n_open, overload_depth = 24, 4
    sweep = []
    for mult in (0.35, 1.0, 3.0):
        rate = mult * cap_rps
        ob = [bodies[i % n_req] for i in range(n_open)]
        delays = [i / rate for i in range(n_open)]
        # pass 1 warms the arrival-pattern-specific compiled variants
        # (staggered admission walks decode-window buckets the all-at-once
        # closed loop never hits; a cold ~1s XLA compile mid-phase would
        # freeze admission and shed everything behind it), pass 2 is timed
        for _ in range(2):
            st, recs, owall = _run_http_phase(
                eng, queue_depth=overload_depth, deadline_s=deadline_s,
                bodies=ob, delays=delays)
        odone = [r for r in recs if r["finish_reason"] in ("length", "eos")]
        ottft = [r["token_times"][0] - r["t_send"] for r in odone
                 if r["token_times"]]
        olat = [r["t_done"] - r["t_send"] for r in odone]
        sweep.append({
            "offered_mult": mult,
            "offered_rps": rate,
            "n_offered": n_open,
            "completed": len(odone),
            "shed": st["shed"],
            "shed_rate": st["shed"] / n_open,
            "deadline_violations": st["expired"],
            "goodput_tokens_per_s": (sum(r["n_tokens"] for r in odone)
                                     / max(owall, 1e-9)),
            "ttft_p50_ms": _pct(ottft, 50), "ttft_p95_ms": _pct(ottft, 95),
            "latency_p50_ms": _pct(olat, 50),
            "latency_p95_ms": _pct(olat, 95),
        })
    low = sweep[0]
    v = {
        "n_requests": n_open,
        "out_tokens": low["completed"] * new_tok,
        "tokens_per_s": low["goodput_tokens_per_s"],
        "latency_p50_ms": low["latency_p50_ms"],
        "latency_p95_ms": low["latency_p95_ms"],
        "ttft_p50_ms": low["ttft_p50_ms"], "ttft_p95_ms": low["ttft_p95_ms"],
        "param_bytes": pbytes,
        "max_new_tokens": new_tok,
        "queue_depth": overload_depth,
        "deadline_s": deadline_s,
        "capacity_rps": cap_rps,
        "sweep": sweep,
    }
    payload["variants"]["http_overload"] = v
    payload["expected_variants"].append("http_overload")
    shed_str = "/".join(f"{p['shed']}" for p in sweep)
    viol_str = "/".join(f"{p['deadline_violations']}" for p in sweep)
    rows.append((
        "serving/http_overload", 1e6 / max(cap_rps, 1e-9),
        f"knee={cap_rps:.0f}rps sweep=0.35x/1x/3x shed={shed_str} "
        f"deadline_viol={viol_str} depth={overload_depth}"))

    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1))
    return rows


def bench_chaos(out_path: str = "BENCH_serving.json") -> List[Row]:
    """Fault-tolerance benchmark, CI-gated by ``check_bench``:

      * ``chaos`` — a fault-free reference run, then the SAME workload on
        the same paged engine with deterministic injectors armed
        (``serving.faults``): a decode-dispatch fault (kills the in-flight
        batch), page-allocator exhaustion (kills one admission), and a
        host-side cancel. Gates: the injectors actually fired
        (``faults`` >= 1), zero leaked pages afterwards, the pump survived
        every fault (``pump_survived``), and every SURVIVING request's
        token stream is BIT-IDENTICAL to its fault-free twin
        (``survivors_identical``) — failure isolation may not perturb
        neighbors' numerics. ``p95_ratio`` (surviving-request p95 vs the
        fault-free p95) is recorded and loosely bounded: survivors usually
        run FASTER (faulted slots free early), so the gate only catches a
        fault-handling stall, not noise.
      * ``admission_feasible`` — a warm ``AdmissionController`` (fed by a
        deadline-free warmup batch) facing a deadline storm where half the
        deadlines are far below the predicted completion time. Gates:
        infeasible requests are shed AT SUBMIT (``shed_infeasible`` >= 1)
        with an honest positive Retry-After, nothing admitted ever blows
        its deadline (``expired`` == 0), and the generous-deadline half
        still completes (``completed`` >= 1) — the predictor must reject
        the impossible without starving the possible."""
    import jax
    from repro import configs
    from repro.core.pruning import param_bytes
    from repro.models import lm
    from repro.serving import (AdmissionController, Engine, Request,
                               SchedulerConfig, Service, ServiceConfig,
                               summarize_results)
    from repro.serving import faults

    cfg = configs.get_smoke_config("qwen3-0.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pbytes = int(param_bytes(params))
    rng = np.random.RandomState(0)
    n_req, new_tok, n_slots, chunk, dsteps = 8, 16, 4, 8, 4
    prompts = [rng.randint(0, cfg.vocab_size, 8 + (5 * i) % 13).tolist()
               for i in range(n_req)]
    mk_reqs = lambda: [Request(prompt=pr, max_new_tokens=new_tok)
                       for pr in prompts]
    arrivals = [2 * i for i in range(n_req)]

    payload = _serving_payload(cfg, n_req, n_slots, chunk, new_tok, dsteps)
    rows: List[Row] = []
    eng = Engine(params, cfg, n_slots=n_slots, max_seq=64,
                 sched=SchedulerConfig(prefill_chunk=chunk,
                                       decode_steps=dsteps),
                 page_size=8, prefix_cache=False)

    # --- fault-free reference (also the warmup that compiles everything)
    eng.run(mk_reqs(), arrival_ticks=arrivals)
    for k in eng.stats:
        eng.stats[k] = 0
    t0 = time.perf_counter()
    ref = eng.run(mk_reqs(), arrival_ticks=arrivals)
    ref_wall = time.perf_counter() - t0
    ref_sum = summarize_results(ref, ref_wall)

    # --- chaos run: same workload, injectors armed. The decode fault at
    # dispatch 3 fails the then-active batch; the alloc fault fails one
    # later admission; staggered arrivals guarantee survivors exist.
    h_dec = faults.inject_decode_fault(eng, at=3)
    h_alloc = faults.inject_alloc_failure(eng, at=12, times=2)
    for k in eng.stats:
        eng.stats[k] = 0
    pump_survived = 1
    try:
        t0 = time.perf_counter()
        chaos = eng.run(mk_reqs(), arrival_ticks=arrivals)
        chaos_wall = time.perf_counter() - t0
    except Exception:
        pump_survived, chaos, chaos_wall = 0, {}, 0.0
    finally:
        h_dec.restore()
        h_alloc.restore()
    survivors = {i: r for i, r in chaos.items()
                 if r.finish_reason != "error"}
    errors = len(chaos) - len(survivors)
    identical = int(bool(survivors) and all(
        r.tokens == ref[i].tokens for i, r in survivors.items()))

    # --- cancel exercise: free a mid-flight request by hand, then drain —
    # the leak gate below covers this path too
    uid_a = eng.submit(Request(prompt=prompts[0], max_new_tokens=new_tok))
    eng.submit(Request(prompt=prompts[1], max_new_tokens=new_tok))
    for _ in range(3):
        eng.step()
    eng.cancel(uid_a)
    while eng.has_work:
        eng.step()

    surv_sum = summarize_results(survivors, chaos_wall)
    v = {
        **surv_sum,
        "param_bytes": pbytes,
        "faults": eng.stats["faults"],
        "cancelled": eng.stats["cancelled"],
        "injected_decode_faults": h_dec.fired,
        "injected_alloc_faults": h_alloc.fired,
        "errors": errors,
        "survivors": len(survivors),
        "survivors_identical": identical,
        "pump_survived": pump_survived,
        "leaked_pages": eng.alloc.pages_in_use,
        "fault_free_tokens_per_s": ref_sum["tokens_per_s"],
        "fault_free_p95_ms": ref_sum["latency_p95_ms"],
        "p95_ratio": (surv_sum["latency_p95_ms"]
                      / max(ref_sum["latency_p95_ms"], 1e-9)),
    }
    payload["variants"]["chaos"] = v
    payload["expected_variants"].append("chaos")
    rows.append((
        "serving/chaos", chaos_wall / max(surv_sum["out_tokens"], 1) * 1e6,
        f"faults={v['faults']} survivors={v['survivors']}/{len(chaos)} "
        f"identical={identical} leaked_pages={v['leaked_pages']} "
        f"pump_survived={pump_survived} p95_ratio={v['p95_ratio']:.2f}"))

    # --- feasibility admission under a deadline storm
    ctrl = AdmissionController()
    svc = Service(eng, ServiceConfig(queue_depth=n_req),
                  admission=ctrl)
    for pr in prompts:                       # deadline-free warmup batch:
        svc.submit(Request(prompt=pr, max_new_tokens=new_tok))
    while svc.has_work:                      # feeds the throughput EWMAs
        svc.step()
    st0 = dict(svc.stats)
    storm, retry_sample, predicted_sample = [], 0.0, 0.0
    t0 = time.perf_counter()
    for i, pr in enumerate(prompts):
        w = ctrl.work_s(len(pr), new_tok)    # predicted solo service time
        # odd requests get a deadline far below any feasible completion;
        # even ones get a generous one the engine can honor even queued
        dl = 0.2 * w if i % 2 else max(30.0, 50 * w)
        t = svc.submit(Request(prompt=pr, max_new_tokens=new_tok),
                       deadline_s=dl)
        if t is None:
            retry_sample = svc.last_shed.get("retry_after_s") or 0.0
            predicted_sample = svc.last_shed.get("predicted_s") or 0.0
        else:
            storm.append(t)
    while svc.has_work:
        svc.step()
    wall = time.perf_counter() - t0
    dst = {k: svc.stats[k] - st0[k] for k in svc.stats}
    done = [t for t in storm if t.finish_reason in ("length", "eos")]
    v = {
        **summarize_results(dict(enumerate(done)), wall),
        "param_bytes": pbytes,
        "submitted": dst["submitted"],
        "completed": dst["completed"],
        "shed": dst["shed"],
        "shed_infeasible": dst["shed_infeasible"],
        "expired": dst["expired"],
        "retry_after_s_sample": retry_sample,
        "predicted_s_sample": predicted_sample,
        "leaked_pages": eng.alloc.pages_in_use,
    }
    payload["variants"]["admission_feasible"] = v
    payload["expected_variants"].append("admission_feasible")
    rows.append((
        "serving/admission_feasible",
        wall / max(v["out_tokens"], 1) * 1e6,
        f"shed_infeasible={v['shed_infeasible']}/{n_req} "
        f"completed={v['completed']} expired={v['expired']} "
        f"retry_after={retry_sample:.3f}s predicted={predicted_sample:.3f}s"))

    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(payload, indent=1))
    return rows


def bench_decode_attention() -> List[Row]:
    """Decode-attention ms/step vs cache capacity (``max_seq`` sweep).

    The length-aware windowed path (static window fixed while ``max_seq``
    grows 4x) must stay ~flat — ``check_bench`` gates on <= 1.3x smallest->
    largest — while the full-cache masked einsum (the pre-windowing decode
    path) scales linearly and is recorded as the contrast row. Runs the xla
    backend (timed gate) and the Pallas kernel in interpret mode (``ref``,
    correctness-on-CI; its absolute times are interpreter overhead, not
    kernel speed)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    from repro.kernels.backend import set_backend

    b, hq, hkv, hd = 4, 8, 4, 64
    window = 64                      # live-length bucket, fixed across sweep
    sweep = (128, 256, 512)          # 4x capacity growth
    key = jax.random.PRNGKey(0)
    rows: List[Row] = []

    for backend, reps in (("xla", 50), ("ref", 5)):
        for max_seq in sweep:
            ks = jax.random.split(jax.random.fold_in(key, max_seq), 3)
            q = jax.random.normal(ks[0], (b, 1, hq, hd), jnp.bfloat16)
            cache = {
                "k": jax.random.normal(ks[1], (b, max_seq, hkv, hd),
                                       jnp.bfloat16),
                "v": jax.random.normal(ks[2], (b, max_seq, hkv, hd),
                                       jnp.bfloat16),
            }
            start = jnp.full((b,), window - 1, jnp.int32)
            prev = set_backend(backend)
            try:
                win_fn = jax.jit(lambda q, c, s: kops.decode_attention(
                    q, c, s, window=window))
                t_win = _timed_min(win_fn, (q, cache, start), reps)
                rows.append((f"decode_attention/{backend}_win/S{max_seq}",
                             t_win * 1e6, f"window={window} slots={b}"))
                if backend == "xla":
                    full_fn = jax.jit(lambda q, c, s: kops.decode_attention(
                        q, c, s, window=None))
                    t_full = _timed_min(full_fn, (q, cache, start), reps)
                    rows.append((f"decode_attention/xla_full/S{max_seq}",
                                 t_full * 1e6,
                                 f"window=None ratio={t_full/t_win:.2f}x"))
            finally:
                set_backend(prev)
    return rows


def bench_prefill_attention() -> List[Row]:
    """Prefill-attention ms/chunk vs cache capacity (``max_seq`` sweep).

    The backend ``prefill_attention`` primitive under the engine's real
    chunked-admission regime (chunk=16 queries, static window fixed while
    ``max_seq`` grows 4x) vs the einsum paths — the TTFT driver HALP argues
    must be measured under chunking, not inferred from whole-prompt numbers.
    ``xla_einsum`` is the WINDOWED masked einsum, i.e. exactly the PR-3
    engine prefill hot path the primitive replaced — the honest gate
    baseline (a full-cache baseline would flatter the primitive ~2x at the
    smallest sweep point); ``xla_einsum_full`` is the un-windowed einsum,
    recorded as the length-unaware contrast like decode's. ``check_bench``
    gates the xla rows: primitive <= 1.1x the windowed einsum, and <= 1.3x
    flat smallest->largest. The ``ref`` rows run the
    Pallas cache-continuation kernel in interpret mode — correctness
    trajectory only; absolute times there are interpreter overhead, not
    kernel speed, so check_bench ignores them. Both gated operands are
    window-fixed, so every sweep point measures the same comparison and
    check_bench judges the ratio at the least-noisy (minimum-ratio) point;
    the sweep here is likewise timed in two interleaved passes so machine
    drift hits all points alike."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    from repro.kernels.backend import set_backend

    b, hq, hkv, hd = 4, 8, 4, 64
    chunk = 16                       # queries per prefill dispatch
    window = 64                      # live-length bucket, fixed across sweep
    sweep = (128, 256, 512)          # 4x capacity growth
    key = jax.random.PRNGKey(1)
    rows: List[Row] = []

    for backend, reps in (("xla", 50), ("ref", 3)):
        prev = set_backend(backend)
        try:
            # build + warm every timed fn for the whole sweep FIRST, then
            # time in two interleaved passes taking the per-point min:
            # slow-machine drift (compile bursts, GC, frequency steps) hits
            # every sweep point alike instead of whichever point happened
            # to be measured last — the flatness/ratio gates compare points
            # against each other, so drift between points is what flakes
            timers = []            # (point name, fn, args)
            for max_seq in sweep:
                ks = jax.random.split(jax.random.fold_in(key, max_seq), 3)
                q = jax.random.normal(ks[0], (b, chunk, hq, hd),
                                      jnp.bfloat16)
                cache = {
                    "k": jax.random.normal(ks[1], (b, max_seq, hkv, hd),
                                           jnp.bfloat16),
                    "v": jax.random.normal(ks[2], (b, max_seq, hkv, hd),
                                           jnp.bfloat16),
                }
                start = jnp.full((b,), window - chunk, jnp.int32)
                args = (q, cache, start)
                timers.append((f"{backend}_win/S{max_seq}", jax.jit(
                    lambda q, c, s: kops.prefill_attention(
                        q, c, s, window=window)), args))
                if backend == "xla":
                    timers.append((f"xla_einsum/S{max_seq}", jax.jit(
                        lambda q, c, s: kops.cached_attention(
                            q, c, s, window=window)), args))
                    timers.append((f"xla_einsum_full/S{max_seq}", jax.jit(
                        lambda q, c, s: kops.cached_attention(
                            q, c, s, window=None)), args))
            t = {}
            for _ in range(2):
                for name, fn, args in timers:
                    t[name] = min(t.get(name, float("inf")),
                                  _timed_min(fn, args, reps))
        finally:
            set_backend(prev)
        for max_seq in sweep:
            t_win = t[f"{backend}_win/S{max_seq}"]
            rows.append((f"prefill_attention/{backend}_win/S{max_seq}",
                         t_win * 1e6,
                         f"chunk={chunk} window={window} slots={b}"))
            if backend == "xla":
                t_ein = t[f"xla_einsum/S{max_seq}"]
                rows.append((f"prefill_attention/xla_einsum/S{max_seq}",
                             t_ein * 1e6,
                             f"window={window} (the replaced hot path) "
                             f"ratio={t_win/t_ein:.2f}x"))
                t_full = t[f"xla_einsum_full/S{max_seq}"]
                rows.append((f"prefill_attention/xla_einsum_full/S{max_seq}",
                             t_full * 1e6,
                             f"window=None ratio={t_full/t_win:.2f}x"))
    return rows


def bench_kernels() -> List[Row]:
    """Kernel micro-bench: bf16 vs W8A8 matmul on the XLA path."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 1024), jnp.bfloat16)
    w = jax.random.normal(key, (1024, 1024), jnp.bfloat16)
    w_q, w_s = ref.quantize_ref(w, axis=0)

    f_bf16 = jax.jit(lambda a, b: a @ b)
    f_int8 = jax.jit(lambda a, bq, bs: ref.int8_matmul_ref(a, bq, bs))
    for name, f, args in [("matmul_bf16", f_bf16, (x, w)),
                          ("matmul_w8a8", f_int8, (x, w_q, w_s))]:
        jax.block_until_ready(f(*args))
        ts = []
        for _ in range(20):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*args))
            ts.append(time.perf_counter() - t0)
        rows.append((f"kernels/{name}", float(np.median(ts)) * 1e6,
                     "cpu-xla"))
    return rows


def bench_roofline_table() -> List[Row]:
    """SRoofline: one row per dry-run cell (from experiments/dryrun)."""
    rows = []
    if not DRYRUN_DIR.exists():
        return [("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    for f in sorted(DRYRUN_DIR.glob("*__baseline.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        step = rl["step_time_lower_bound_s"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                     step * 1e6,
                     f"dom={rl['dominant'][2:]} useful={rl['useful_flops_ratio']:.2f}"))
    return rows


BENCHES = [
    bench_table1_mobilenetv3,
    bench_table2_resnet18,
    bench_complexity_analysis,
    bench_layerwise_sparsity,
    bench_energy,
    bench_lm_hqp_serving,
    bench_serving,
    bench_speculative,
    bench_paged,
    bench_http,
    bench_chaos,
    bench_decode_attention,
    bench_prefill_attention,
    bench_kernels,
    bench_roofline_table,
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench name suffixes, e.g. "
                         "'serving,kernels'")
    ap.add_argument("--json", default=None,
                    help="write all rows (+ the serving payload) to this "
                         "schema-tagged JSON file (CI perf trajectory)")
    args = ap.parse_args(argv)

    selected = BENCHES
    if args.only:
        want = [w.strip() for w in args.only.split(",") if w.strip()]
        selected = [b for b in BENCHES
                    if any(b.__name__ == f"bench_{w}" or b.__name__ == w
                           for w in want)]
        missing = [w for w in want
                   if not any(b.__name__ in (f"bench_{w}", w)
                              for b in BENCHES)]
        if missing:
            raise SystemExit(f"unknown benches: {missing}; known: "
                             f"{[b.__name__ for b in BENCHES]}")

    all_rows: List[Row] = []
    errors: List[str] = []
    print("name,us_per_call,derived")
    for bench in selected:
        try:
            for name, us, derived in bench():
                all_rows.append((name, us, derived))
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # keep the harness running
            errors.append(f"{bench.__name__}:{type(e).__name__}:{e}")
            print(f"{bench.__name__},nan,ERROR:{type(e).__name__}:{e}")

    if args.json:
        payload = {
            "schema": BENCH_SCHEMA,
            "benches": [b.__name__ for b in selected],
            "rows": [{"name": n, "us_per_call": u, "derived": d}
                     for n, u, d in all_rows],
            "errors": errors,
        }
        if _LAST_SERVING:
            payload["serving"] = _LAST_SERVING
        pathlib.Path(args.json).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {args.json} ({len(all_rows)} rows)")
    # CI contract: selected benches must produce rows and no errors
    return 1 if (errors and args.json) else 0


if __name__ == "__main__":
    raise SystemExit(main())
