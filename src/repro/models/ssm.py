"""Mamba (selective SSM) block: chunked parallel scan + single-step decode.

Recurrence: h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t h_t + D x_t

Training/prefill materializes per-chunk (B, chunk, d_in, d_state) scan elements
only (lax.scan over chunks, associative_scan within a chunk), keeping the
transient footprint ~chunk/S of the naive parallel scan. The d_in axis is the
TP-sharded axis (states stay local; x_proj/out_proj contractions reduce over it).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _dt_rank(cfg) -> int:
    s = cfg.ssm
    return s.dt_rank or -(-cfg.d_model // 16)


def mamba_init(key, cfg) -> dict:
    s = cfg.ssm
    d, d_in = cfg.d_model, cfg.ssm.expand * cfg.d_model
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_proj": L.linear_init(ks[0], d, 2 * d_in),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_in)) * 0.1
                   ).astype(jnp.float32),
        "x_proj": L.linear_init(ks[2], d_in, r + 2 * s.d_state),
        "dt_proj": {"w": L.he_init(ks[3], (r, d_in), jnp.float32),
                    "b": jnp.full((d_in,), -4.6, jnp.float32)},  # softplus≈0.01
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": L.linear_init(ks[5], d_in, d),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C), w: (K, C) -> causal depthwise conv, (B, S, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):                       # K is 4: unrolled, fuses to adds
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i]
    return out.astype(x.dtype)


def _ssm_params(p: dict, xc: jax.Array, cfg):
    """xc: (..., d_in) conv'd input -> (dt, B, C) selective params."""
    s = cfg.ssm
    r = _dt_rank(cfg)
    proj = L.dense(xc, p["x_proj"]).astype(jnp.float32)
    dt_in, b, c = jnp.split(proj, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("...r,rd->...d", dt_in, p["dt_proj"]["w"])
                         + p["dt_proj"]["b"])            # (..., d_in)
    return dt, b, c


def _scan_chunk(h0: jax.Array, a_bar: jax.Array, bx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + bx_t within a chunk.

    a_bar, bx: (B, c, d_in, n). Returns (all h, final h)."""
    bx = bx.at[:, 0].add(a_bar[:, 0] * h0)

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(op, (a_bar, bx), axis=1)
    return h, h[:, -1]


def mamba_forward(p: dict, cfg, x: jax.Array,
                  state: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d). state (decode): {"h": (B,d_in,n) f32, "conv": (B,K-1,d_in)}."""
    s = cfg.ssm
    d_in = p["conv_w"].shape[-1]                  # shape-derived (pruning)
    b_sz, seq, _ = x.shape
    xz = L.dense(x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)                   # (B, S, d_in)
    a = -jnp.exp(p["a_log"])                             # (d_in, n)

    if state is not None and seq == 1:
        # -------- single-token decode --------
        conv_buf = jnp.concatenate([state["conv"], xin.astype(jnp.float32)], 1)
        xc = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"])[:, None, :]
        xc = jax.nn.silu(xc)
        dt, bb, cc = _ssm_params(p, xc, cfg)             # (B,1,d_in),(B,1,n)
        a_bar = jnp.exp(dt[:, 0, :, None] * a)           # (B, d_in, n)
        h = a_bar * state["h"] + (dt[:, 0, :, None] * bb[:, 0, None, :]
                                  * xc[:, 0, :, None].astype(jnp.float32))
        y = jnp.einsum("bdn,bn->bd", h, cc[:, 0])[:, None, :]
        y = y + p["d_skip"] * xc.astype(jnp.float32)
        new_state = {"h": h, "conv": conv_buf[:, 1:]}
    else:
        # -------- chunked parallel prefill/train --------
        # (optionally seeded with a decode state, for cache-filling prefill)
        if state is not None:
            xpad = jnp.concatenate([state["conv"], xin.astype(jnp.float32)], 1)
            xc = jax.nn.silu(_causal_depthwise_conv(
                xpad, p["conv_w"])[:, s.d_conv - 1:, :]).astype(xin.dtype)
            new_conv = xpad[:, -(s.d_conv - 1):, :]
            h0 = state["h"]
        else:
            xc = jax.nn.silu(_causal_depthwise_conv(xin, p["conv_w"]))
            new_conv = None
            h0 = jnp.zeros((b_sz, d_in, s.d_state), jnp.float32)
        dt, bb, cc = _ssm_params(p, xc, cfg)             # (B,S,d_in),(B,S,n)
        chunk = min(s.chunk, seq)
        assert seq % chunk == 0
        n_chunks = seq // chunk

        def step(h0, xs):
            dt_c, b_c, c_c, x_c = xs                     # (B, c, ...)
            a_bar = jnp.exp(dt_c[..., None] * a)         # (B,c,d_in,n)
            bx = (dt_c[..., None] * b_c[:, :, None, :]
                  * x_c[..., None].astype(jnp.float32))
            hs, h_last = _scan_chunk(h0, a_bar, bx)
            y = jnp.einsum("bcdn,bcn->bcd", hs, c_c)
            return h_last, y

        def r(t):                                        # (B,S,...)->(nc,B,c,...)
            return jnp.moveaxis(
                t.reshape(b_sz, n_chunks, chunk, *t.shape[2:]), 1, 0)

        h_last, ys = jax.lax.scan(step, h0, (r(dt), r(bb), r(cc), r(xc)))
        y = jnp.moveaxis(ys, 0, 1).reshape(b_sz, seq, d_in)
        y = y + p["d_skip"] * xc.astype(jnp.float32)
        new_state = (None if state is None
                     else {"h": h_last, "conv": new_conv})

    out = y.astype(L.COMPUTE_DTYPE) * jax.nn.silu(z)
    return L.dense(out, p["out_proj"]), new_state


def init_mamba_state(batch: int, cfg, d_in: Optional[int] = None) -> dict:
    """``d_in`` override: channel width of an HQP-compacted block."""
    s = cfg.ssm
    if d_in is None:
        d_in = s.expand * cfg.d_model
    return {"h": jnp.zeros((batch, d_in, s.d_state), jnp.float32),
            "conv": jnp.zeros((batch, s.d_conv - 1, d_in), jnp.float32)}
