"""ResNet-18 and MobileNetV3-Small in pure JAX (the paper's two test archs).

Functional params-as-pytrees; all shapes are read from params (not config) so
HQP structural pruning is pure *parameter surgery*: masking zeroes channels
(for the conditional-loop evaluation) and compaction physically removes them
(the deploy artifact) without touching model code.

Layout NHWC, weights HWIO. BatchNorm carries running stats in a separate
"stats" subtree (functionally updated during training, EMA for eval).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

BN_MOM = 0.9


# ------------------------------------------------------------------ prims
def conv_init(key, k: int, c_in: int, c_out: int, depthwise: bool = False):
    fan = k * k * (1 if depthwise else c_in)
    shape = (k, k, 1 if depthwise else c_in, c_out)
    return (jax.random.normal(key, shape) * (2.0 / fan) ** 0.5).astype(jnp.float32)


def conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def bn_init(c: int):
    return ({"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))},
            {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))})


def bn_apply(p, stats, x, train: bool):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_stats = {"mean": BN_MOM * stats["mean"] + (1 - BN_MOM) * mean,
                     "var": BN_MOM * stats["var"] + (1 - BN_MOM) * var}
    else:
        mean, var = stats["mean"], stats["var"]
        new_stats = stats
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y, new_stats


def hswish(x):
    return x * jax.nn.relu6(x + 3.0) / 6.0


def hsigmoid(x):
    return jax.nn.relu6(x + 3.0) / 6.0


# ====================================================================
# ResNet-18
# ====================================================================
RESNET_STAGES = ((2, 64, 1), (2, 128, 2), (2, 256, 2), (2, 512, 2))


def _basic_block_init(key, c_in, c_out, stride):
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {}
    st: Dict[str, Any] = {}
    p["conv1"] = conv_init(ks[0], 3, c_in, c_out)
    p["bn1"], st["bn1"] = bn_init(c_out)
    p["conv2"] = conv_init(ks[1], 3, c_out, c_out)
    p["bn2"], st["bn2"] = bn_init(c_out)
    if stride != 1 or c_in != c_out:
        p["down"] = conv_init(ks[2], 1, c_in, c_out)
        p["bn_down"], st["bn_down"] = bn_init(c_out)
    return p, st


def resnet18_init(key, cfg) -> dict:
    wm = cfg.width_mult
    ks = jax.random.split(key, 2 + sum(s[0] for s in RESNET_STAGES))
    params: Dict[str, Any] = {}
    stats: Dict[str, Any] = {}
    c = int(64 * wm)
    params["stem"] = conv_init(ks[0], 3, 3, c)
    params["bn_stem"], stats["bn_stem"] = bn_init(c)
    ki = 1
    for si, (n_blocks, width, stride) in enumerate(RESNET_STAGES):
        c_out = int(width * wm)
        for bi in range(n_blocks):
            p, st = _basic_block_init(ks[ki], c, c_out, stride if bi == 0 else 1)
            params[f"s{si}b{bi}"] = p
            stats[f"s{si}b{bi}"] = st
            c = c_out
            ki += 1
    params["fc"] = {"w": (jax.random.normal(ks[ki], (c, cfg.n_classes))
                          * c ** -0.5).astype(jnp.float32),
                    "b": jnp.zeros((cfg.n_classes,))}
    return {"params": params, "stats": stats}


def _basic_block_apply(p, st, x, stride, train, actq=None, name=""):
    tap = actq.tap if actq is not None else (lambda n, v: v)
    new_st = {}
    h = conv(x, p["conv1"], stride)
    h, new_st["bn1"] = bn_apply(p["bn1"], st["bn1"], h, train)
    h = tap(f"{name}/act1", jax.nn.relu(h))
    h = conv(h, p["conv2"], 1)
    h, new_st["bn2"] = bn_apply(p["bn2"], st["bn2"], h, train)
    if "down" in p:
        x = conv(x, p["down"], stride)
        x, new_st["bn_down"] = bn_apply(p["bn_down"], st["bn_down"], x, train)
    return tap(f"{name}/out", jax.nn.relu(h + x)), new_st


def resnet18_apply(variables: dict, x: jax.Array, train: bool = False,
                   actq=None):
    tap = actq.tap if actq is not None else (lambda n, v: v)
    p, st = variables["params"], variables["stats"]
    new_st: Dict[str, Any] = {}
    h = conv(tap("input", x), p["stem"], 1)
    h, new_st["bn_stem"] = bn_apply(p["bn_stem"], st["bn_stem"], h, train)
    h = tap("stem", jax.nn.relu(h))
    for si, (n_blocks, _, stride) in enumerate(RESNET_STAGES):
        for bi in range(n_blocks):
            name = f"s{si}b{bi}"
            h, new_st[name] = _basic_block_apply(
                p[name], st[name], h, stride if bi == 0 else 1, train,
                actq, name)
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ p["fc"]["w"] + p["fc"]["b"]
    return logits, new_st


# ====================================================================
# MobileNetV3-Small (strides adapted to 32px input)
# ====================================================================
# (kernel, expansion, out, SE, hswish?, stride)
MBV3S_BLOCKS: List[Tuple[int, int, int, bool, bool, int]] = [
    (3, 16, 16, True, False, 1),
    (3, 72, 24, False, False, 2),
    (3, 88, 24, False, False, 1),
    (5, 96, 40, True, True, 2),
    (5, 240, 40, True, True, 1),
    (5, 240, 40, True, True, 1),
    (5, 120, 48, True, True, 1),
    (5, 144, 48, True, True, 1),
    (5, 288, 96, True, True, 2),
    (5, 576, 96, True, True, 1),
    (5, 576, 96, True, True, 1),
]


def _bneck_init(key, c_in, k, exp, out, se):
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {}
    st: Dict[str, Any] = {}
    p["expand"] = conv_init(ks[0], 1, c_in, exp)
    p["bn_e"], st["bn_e"] = bn_init(exp)
    p["dw"] = conv_init(ks[1], k, exp, exp, depthwise=True)
    p["bn_d"], st["bn_d"] = bn_init(exp)
    if se:
        c_se = max(8, exp // 4)
        p["se_down"] = {"w": conv_init(ks[2], 1, exp, c_se),
                        "b": jnp.zeros((c_se,))}
        p["se_up"] = {"w": conv_init(ks[3], 1, c_se, exp),
                      "b": jnp.zeros((exp,))}
    p["project"] = conv_init(ks[4], 1, exp, out)
    p["bn_p"], st["bn_p"] = bn_init(out)
    return p, st


def mobilenetv3s_init(key, cfg) -> dict:
    wm = cfg.width_mult
    ks = jax.random.split(key, len(MBV3S_BLOCKS) + 3)
    params: Dict[str, Any] = {}
    stats: Dict[str, Any] = {}
    c = int(16 * wm)
    params["stem"] = conv_init(ks[0], 3, 3, c)
    params["bn_stem"], stats["bn_stem"] = bn_init(c)
    for i, (k, exp, out, se, hs, stride) in enumerate(MBV3S_BLOCKS):
        p, st = _bneck_init(ks[i + 1], c, k, int(exp * wm), int(out * wm), se)
        params[f"b{i}"] = p
        stats[f"b{i}"] = st
        c = int(out * wm)
    c_head = int(576 * wm)
    params["head"] = conv_init(ks[-2], 1, c, c_head)
    params["bn_head"], stats["bn_head"] = bn_init(c_head)
    params["fc"] = {"w": (jax.random.normal(ks[-1], (c_head, cfg.n_classes))
                          * c_head ** -0.5).astype(jnp.float32),
                    "b": jnp.zeros((cfg.n_classes,))}
    return {"params": params, "stats": stats}


def _bneck_apply(p, st, x, k, se, hs, stride, train, actq=None, name=""):
    tap = actq.tap if actq is not None else (lambda n, v: v)
    act = hswish if hs else jax.nn.relu
    new_st = {}
    exp = p["expand"].shape[-1]
    h = conv(x, p["expand"], 1)
    h, new_st["bn_e"] = bn_apply(p["bn_e"], st["bn_e"], h, train)
    h = tap(f"{name}/e", act(h))
    h = conv(h, p["dw"], stride, groups=exp)
    h, new_st["bn_d"] = bn_apply(p["bn_d"], st["bn_d"], h, train)
    h = tap(f"{name}/d", act(h))
    if se:
        pooled = jnp.mean(h, axis=(1, 2), keepdims=True)
        a = jax.nn.relu(conv(pooled, p["se_down"]["w"]) + p["se_down"]["b"])
        a = hsigmoid(conv(a, p["se_up"]["w"]) + p["se_up"]["b"])
        h = h * a
    h = conv(h, p["project"], 1)
    h, new_st["bn_p"] = bn_apply(p["bn_p"], st["bn_p"], h, train)
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return tap(f"{name}/out", h), new_st


def mobilenetv3s_apply(variables: dict, x: jax.Array, train: bool = False,
                       actq=None):
    tap = actq.tap if actq is not None else (lambda n, v: v)
    p, st = variables["params"], variables["stats"]
    new_st: Dict[str, Any] = {}
    h = conv(tap("input", x), p["stem"], 1)
    h, new_st["bn_stem"] = bn_apply(p["bn_stem"], st["bn_stem"], h, train)
    h = tap("stem", hswish(h))
    for i, (k, exp, out, se, hs, stride) in enumerate(MBV3S_BLOCKS):
        name = f"b{i}"
        h, new_st[name] = _bneck_apply(p[name], st[name], h, k, se, hs,
                                       stride, train, actq, name)
    h = conv(h, p["head"], 1)
    h, new_st["bn_head"] = bn_apply(p["bn_head"], st["bn_head"], h, train)
    h = tap("head", hswish(h))
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ p["fc"]["w"] + p["fc"]["b"]
    return logits, new_st


# ------------------------------------------------------------------ facade
def cnn_init(key, cfg) -> dict:
    return (resnet18_init if cfg.arch == "resnet18" else mobilenetv3s_init)(key, cfg)


def cnn_apply(cfg, variables, x, train: bool = False, actq=None):
    fn = resnet18_apply if cfg.arch == "resnet18" else mobilenetv3s_apply
    return fn(variables, x, train, actq)
