from repro.models import lm  # noqa: F401

__all__ = ["lm"]
