"""GQA attention with three STATIC attend routes: train | prefill | decode.

Train (no cache) uses an online-softmax KV-chunked scan (pure jnp, XLA path —
its FLOPs/bytes are visible to ``cost_analysis`` for the roofline; the Pallas
TPU kernel in ``repro.kernels.flash_attention`` is the deployment hot path
validated against it).

Prefill and decode attend a (possibly INT8-quantized) KV cache laid out
(B, S, Hkv, hd) through the backend ``prefill_attention`` /
``decode_attention`` primitives (Pallas cache-continuation / split-KV
kernels on TPU; the masked einsum on xla). The sequence axis can be sharded
across the ``model`` mesh axis (flash-decoding style sequence parallelism:
local partial softmax stats + tiny cross-shard reductions, inserted
automatically by GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L

NEG_INF = -1e30


# ------------------------------------------------------------------ params
def attention_init(key, cfg) -> dict:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.linear_init(k1, cfg.d_model, cfg.n_heads * hd),
        "wk": L.linear_init(k2, cfg.d_model, cfg.n_kv_heads * hd),
        "wv": L.linear_init(k3, cfg.d_model, cfg.n_kv_heads * hd),
        "wo": L.linear_init(k4, cfg.n_heads * hd, cfg.d_model),
    }


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd)


# ------------------------------------------------------------------ flash
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, chunk_kv: int = 1024,
                    q_offset: int = 0) -> jax.Array:
    """Online-softmax attention, KV-chunked.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd), Hq = G * Hkv. Skv may be
    ragged (any length): K/V are zero-padded to a ``chunk_kv`` multiple and
    the tail is masked by position. Returns (B, Sq, Hq, hd). Scores and
    stats in f32.

    Causal semantics are ABSOLUTE-position: query i sits at position
    ``q_offset + i`` and sees ``kv_pos <= q_offset + i``. The default
    ``q_offset=0`` means queries are the FIRST Sq positions — the same
    convention as ``kernels.ref.flash_attention_ref`` and the ``start``
    argument of the cache-attention primitives (there is exactly one
    Sq<Skv convention in the repo; tests cross-check all three).
    """
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = hd ** -0.5
    chunk_kv = min(chunk_kv, skv)
    pad_kv = (-skv) % chunk_kv        # ragged Skv (prime lengths, odd prompt
    if pad_kv:                        # sizes): zero-pad, mask the tail below
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    n_chunks = (skv + pad_kv) // chunk_kv

    # bf16 operands, f32 accumulation (MXU native mode).
    qs = (q.astype(jnp.float32) * scale).astype(L.COMPUTE_DTYPE)
    qs = qs.reshape(b, sq, hkv, g, hd)
    kc = k.reshape(b, n_chunks, chunk_kv, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk_kv, hkv, hd)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        k_j, v_j, j = xs
        kv_pos = j * chunk_kv + jnp.arange(chunk_kv)
        # scores: (B, Sq, Hkv, G, Ckv)
        s = jnp.einsum("bqhgd,bchd->bqhgc", qs, k_j,
                       preferred_element_type=jnp.float32)
        # padded tail positions are masked unconditionally (the causal limit
        # alone would leave them visible to queries past skv-1)
        mask = jnp.broadcast_to(kv_pos[None, :] < skv, (sq, chunk_kv))
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])  # (Sq, Ckv)
        if causal or pad_kv:
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgc,bchd->bqhgd", p.astype(L.COMPUTE_DTYPE), v_j,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    ks = jnp.moveaxis(kc, 1, 0)
    vs = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (ks, vs, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, hq, hd).astype(L.COMPUTE_DTYPE)


# ------------------------------------------------------------------ KV cache
@dataclasses.dataclass
class CacheSpec:
    quantized: bool = False     # INT8 KV cache (beyond-paper: HQP applied to KV)


def init_kv_cache(batch: int, max_seq: int, n_kv_heads: int, hd: int,
                  quantized: bool = False, paged: bool = False) -> dict:
    """``paged=True`` marks the leaves as a shared page arena (``batch`` is
    ``total_pages``, ``max_seq`` is ``page_size``). bf16 caches — paged
    arena and contiguous pool alike — are stored as raw uint16 words: XLA
    CPU's float-normalization pass rewrites bf16 scatter/dynamic_update_
    slice through f32 converts, copying the whole buffer on every write —
    uint16 data movement stays in place under donation
    (``kernels.kv_layout.to_store/from_store`` own the lossless bitcasts at
    the read/write boundaries). int8 quantized leaves scatter in place
    natively and keep their dtype in both layouts."""
    if quantized:
        return {
            "k_q": jnp.zeros((batch, max_seq, n_kv_heads, hd), jnp.int8),
            "v_q": jnp.zeros((batch, max_seq, n_kv_heads, hd), jnp.int8),
            "k_s": jnp.zeros((batch, max_seq, n_kv_heads), jnp.float32),
            "v_s": jnp.zeros((batch, max_seq, n_kv_heads), jnp.float32),
        }
    del paged   # dtype no longer depends on the layout
    dt = (jnp.uint16 if L.COMPUTE_DTYPE == jnp.bfloat16
          else L.COMPUTE_DTYPE)
    return {
        "k": jnp.zeros((batch, max_seq, n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_seq, n_kv_heads, hd), dt),
    }


def _quant_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per (batch, pos, head) symmetric int8. x: (B, S, Hkv, hd)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def update_kv_cache(cache: dict, k_new: jax.Array, v_new: jax.Array,
                    pos: jax.Array,
                    pages: Optional[jax.Array] = None) -> dict:
    """Insert (B, Sn, Hkv, hd) at position ``pos``.

    ``pos`` is a scalar (all rows write at the same offset — the single-batch
    serve path) or a (B,) vector of per-slot offsets (the continuous-batching
    engine, where every slot sits at its own sequence position). The vector
    path is a per-row scatter (vmapped dynamic_update_slice).

    ``pages`` (B, max_pages) int32 marks the cache as a PAGED arena —
    leaves are (n_pages, page_size, ...) with no batch axis — and the write
    becomes a flat per-element scatter through the page table
    (``kv_layout.scatter_pages``): logical position p of row b lands at
    ``arena[pages[b, p // page_size], p % page_size]``. The per-token
    values (INT8 quant included — it is per-(pos, head)) are identical to
    the contiguous write, which is what keeps prefix-cache page reuse
    bit-exact across requests."""
    if "k_q" in cache:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        new = {"k_q": kq, "v_q": vq, "k_s": ks, "v_s": vs}
    else:
        # bf16 caches store raw uint16 words (init_kv_cache) —
        # scatter_pages bitcasts the update itself, so keep it in compute
        # dtype there; the contiguous DUS paths bitcast here
        new = {"k": k_new.astype(L.COMPUTE_DTYPE),
               "v": v_new.astype(L.COMPUTE_DTYPE)}
    if pages is not None:
        from repro.kernels.kv_layout import scatter_pages
        return {key: scatter_pages(cache[key], new[key], pages, pos)
                for key in cache}
    from repro.kernels.kv_layout import to_store
    new = {key: to_store(val, cache[key].dtype) for key, val in new.items()}
    if jnp.ndim(pos) == 0:
        def scatter(buf, upd):
            idx = (0, pos) + (0,) * (buf.ndim - 2)
            return jax.lax.dynamic_update_slice(buf, upd, idx)
    else:
        def scatter(buf, upd):
            def row(b_row, u_row, p):
                idx = (p,) + (0,) * (b_row.ndim - 1)
                return jax.lax.dynamic_update_slice(b_row, u_row, idx)
            return jax.vmap(row)(buf, upd, pos)
    return {key: scatter(cache[key], new[key]) for key in cache}


def cached_attention(q: jax.Array, cache: dict, start: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """Masked-einsum oracle for cache attention (NOT the hot path — the
    ``prefill_attention``/``decode_attention`` backend primitives are; on
    the ``xla`` backend they resolve to exactly this einsum).

    q: (B, Sq, Hq, hd) queries at absolute positions start..start+Sq-1,
    attending a cache that already holds positions [0, start+Sq). ``start``
    is scalar or (B,) (per-slot positions under continuous batching). Query
    i attends cache positions <= start+i — the absolute causal limit every
    attend route in the repo shares, which is why a chunked prefill produces
    bit-identical logits to a whole-prompt prefill.

    ``window`` (STATIC int, host-bucketed >= start+Sq, None = full buffer)
    restricts the einsum to the visible prefix, so traffic is O(window)
    instead of O(max_seq) — positions past the window contribute
    exp(-inf) = 0 exactly, keeping the windowed path bit-identical to the
    full-mask einsum. The INT8 cache is read as int8; per-(pos,head) dequant
    rides on the score/probability matrices."""
    return ops.cached_attention(q, cache, start, window)


# ------------------------------------------------------------------ block fwd
def _context_parallel(q, k, v, ctx):
    """Re-shard attention over the SEQUENCE instead of heads.

    When n_kv_heads doesn't divide the TP width (GQA-8 on a 16-wide model
    axis — six of the ten assigned archs), GSPMD splits the head_dim across
    ranks and must all-reduce full f32 score tensors every KV chunk
    (arctic-480b: 1.9 GB x 140 per step — EXPERIMENTS.md §Perf iteration 3).
    Sharding queries over (model=sequence) keeps every score tile local; the
    price is one KV broadcast per layer (B·S·Hkv·hd bf16, ≪ the scores)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    bspec = ctx.batch_spec()[0]
    mdl = ctx.model_axis
    sh = lambda t, spec: jax.lax.with_sharding_constraint(
        t, NamedSharding(ctx.mesh, spec))
    q = sh(q, P(bspec, mdl, None, None))
    k = sh(k, P(bspec, None, None, None))
    v = sh(v, P(bspec, None, None, None))
    return q, k, v


TRAIN, PREFILL, DECODE = "train", "prefill", "decode"
ROUTES = (TRAIN, PREFILL, DECODE)


def attention_forward(p: dict, cfg, x: jax.Array, positions: jax.Array,
                      cache: Optional[dict] = None,
                      cur_len: Optional[jax.Array] = None,
                      ctx=None, window: Optional[int] = None,
                      route: Optional[str] = None,
                      pages: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, Optional[dict]]:
    """Full attention sub-block (no norm/residual — block owns those).

    ``route`` is the STATIC attend route — ``"train" | "prefill" |
    "decode"`` — replacing the old fragile boolean ``decode`` tri-state
    (where a 1-token prefill tail chunk had to remember to pass
    ``decode=False`` or silently take kernel numerics that break the
    engine's token-identity contract). The three routes:

      train    cache is None: local flash attention over the fresh K/V
      prefill  cache given, x is (B, Sq, d): write K/V, attend the cache
               through the backend ``prefill_attention`` primitive —
               Sq == 1 (a prompt's tail chunk) is legal and STAYS here
      decode   cache given, x is (B, 1, d): write K/V, attend through the
               backend ``decode_attention`` primitive

    ``route=None`` infers: no cache -> train; else S == 1 -> decode, S > 1
    -> prefill. Engine/serving callers pass the route explicitly — the
    inference is a convenience for serial/test code, and a tail chunk left
    to inference would (correctly for serial, wrongly for chunked prefill)
    land on decode, which is why the engine never relies on it.

    ``window``: static visible-window bound (see ``ops``) — cache writes
    always hit the full buffer, only the attend is windowed. ``cur_len`` =
    tokens already in cache (scalar or (B,) per-slot).

    ``pages`` (B, max_pages) int32: the cache is a PAGED arena — both the
    KV write and the attend indirect through the page table (``ops`` owns
    the window-as-page-prefix plumbing; the train route never pages).
    """
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    # head counts derive from (possibly HQP-compacted) param shapes
    n_heads = L.out_features(p["wq"]) // hd
    n_kv = L.out_features(p["wk"]) // hd
    q = _split_heads(L.dense(x, p["wq"]), n_heads, hd)
    k = _split_heads(L.dense(x, p["wk"]), n_kv, hd)
    v = _split_heads(L.dense(x, p["wv"]), n_kv, hd)
    if cfg.qk_norm:
        q, k = L.l2norm(q), L.l2norm(k)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    use_cp = (s > 1 and ctx is not None and not ctx.pure_dp
              and ctx.mesh.size > 1 and ctx.tp_size > 1
              and n_kv % ctx.tp_size != 0 and s % ctx.tp_size == 0)
    if use_cp:
        q, k, v = _context_parallel(q, k, v, ctx)

    assert route is None or route in ROUTES, route
    if cache is None:
        assert route in (None, TRAIN), \
            f"route {route!r} needs a cache; got none"
        o = flash_attention(q, k, v, causal=True, chunk_kv=cfg.attn_chunk_kv)
        new_cache = None
    else:
        assert route != TRAIN, "train route cannot take a cache"
        # cache-filling prefill (s > 1) and decode (s == 1) share the same
        # semantics: write K/V, then attend the cache with per-query causal
        # limits — both through backend primitives (Pallas online-softmax
        # kernels on TPU; the xla registration of either primitive is the
        # identical masked einsum). Chunked prefill continuation
        # (cur_len > 0) needs the cache read — a local flash attend would
        # miss the earlier chunks.
        new_cache = update_kv_cache(cache, k, v, cur_len, pages=pages)
        r = route or (DECODE if s == 1 else PREFILL)
        if r == DECODE:
            assert s == 1, f"decode attend requires a single query, got {s}"
            o = ops.decode_attention(q, new_cache, cur_len, window, pages)
        else:
            o = ops.prefill_attention(q, new_cache, cur_len, window, pages)
    out = L.dense(o.reshape(b, s, n_heads * hd), p["wo"])
    return out, new_cache
