"""Mixture-of-Experts with expert parallelism via shard_map.

Design (see DESIGN.md §5): activations entering a MoE layer are replicated
across the ``model`` axis and sharded over the data axes, while experts are
sharded over ``model`` (EP). Each device therefore:

  1. computes the router for its *local* tokens (router weights replicated),
  2. gathers the tokens assigned to its *own* experts into a fixed-capacity
     (E_local, C, d) buffer (sort-based dispatch — no dense one-hot einsum,
     whose dispatch matmul would cost O(N·d·E·C) fake FLOPs),
  3. runs the expert FFNs as one batched matmul (MXU-friendly),
  4. scatter-adds weighted outputs back to token slots, and
  5. psums over ``model`` — which doubles as the tensor-parallel reduction.

No token ever leaves its data shard: EP costs one (N_local, d) all-reduce per
MoE layer instead of two all-to-alls, and composes with FSDP on the data axes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import layers as L


def moe_init(key, cfg) -> dict:
    m = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, m.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": {"w": L.he_init(k1, (d, e), jnp.float32),
                   "b": jnp.zeros((e,), jnp.float32)},
        "gate": {"w": L.he_init(k2, (e, d, ff), L.COMPUTE_DTYPE, fan_in=d)},
        "up": {"w": L.he_init(k3, (e, d, ff), L.COMPUTE_DTYPE, fan_in=d)},
        "down": {"w": L.he_init(k4, (e, ff, d), L.COMPUTE_DTYPE, fan_in=ff)},
    }


def _capacity(n_tokens: int, cfg, no_drop: bool = False) -> int:
    """Per-expert buffer rows. ``no_drop``: an expert appears at most once in
    a token's top-k, so capacity == n_tokens holds every routable pair —
    batched prefill then computes exactly what per-token decode computes
    (the serving-consistency invariant; train keeps capacity_factor drops)."""
    if no_drop:
        return n_tokens
    m = cfg.moe
    c = int(m.experts_per_token * n_tokens * m.capacity_factor / m.n_experts) + 1
    return max(4, min(c, n_tokens))


def _expert_ffn(xb: jax.Array, p: dict) -> jax.Array:
    """xb: (E_loc, C, d); expert weights (E_loc, d, ff)/(E_loc, ff, d).

    ``L.dense`` dispatches per expert inside the vmap — FP dicts and
    ``QuantizedLinear`` nodes (per-expert out-channel scales) share one path."""
    def one(x, pg, pu, pd):
        h = jax.nn.silu(L.dense(x, pg)) * L.dense(x, pu)
        return L.dense(h, pd)
    return jax.vmap(one)(xb, p["gate"], p["up"], p["down"])


def _moe_local(x: jax.Array, params: dict, cfg, e_start: jax.Array,
               e_local: int, capacity: int, data_axes: Tuple[str, ...],
               model_axis: str, with_aux: bool):
    """Per-shard MoE body. x: (N_loc, d) local tokens, model-replicated."""
    m = cfg.moe
    n, d = x.shape
    k = m.experts_per_token
    e = params["router"]["w"].shape[-1]

    logits = (jnp.dot(x.astype(jnp.float32), params["router"]["w"])
              + params["router"]["b"])                               # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                  # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # flat (token, expert) pairs, sorted by expert for capacity ranking
    flat_e = expert_idx.reshape(-1)                                  # (N*k,)
    flat_g = gate_vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e)                                      # stable
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[se]                            # pos in expert

    local = (se >= e_start) & (se < e_start + e_local) & (rank < capacity)
    slot = jnp.where(local, (se - e_start) * capacity + rank, e_local * capacity)

    # dispatch: gather tokens -> (E_loc*C, d) buffer (extra row = drop bin)
    xb = jnp.zeros((e_local * capacity + 1, d), x.dtype).at[slot].set(
        x[st], mode="drop")
    yb = _expert_ffn(xb[:-1].reshape(e_local, capacity, d), params)
    yb = yb.reshape(e_local * capacity, d)

    # combine: weighted scatter-add back to token slots. Keep the combine and
    # the cross-shard reduction in bf16: the psum'd (N_loc, d) tensor is the
    # single largest MoE collective (f32 here doubled arctic-480b's per-layer
    # all-reduce to 1.9 GB x 140 — EXPERIMENTS.md §Perf iteration 2).
    contrib = yb[jnp.minimum(slot, e_local * capacity - 1)]
    contrib = (contrib.astype(jnp.float32)
               * (sg * local)[:, None]).astype(L.COMPUTE_DTYPE)
    out = jnp.zeros((n, d), L.COMPUTE_DTYPE).at[st].add(contrib, mode="drop")
    out = jax.lax.psum(out, model_axis)

    aux = {}
    if with_aux:
        # Switch-style load-balance + router z-loss, averaged globally.
        frac = counts.astype(jnp.float32) / (n * k)                  # f_e
        mean_prob = jnp.mean(probs, axis=0)                          # P_e
        lb = e * jnp.sum(frac * mean_prob)
        z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        if data_axes:
            for a in data_axes:
                lb = jax.lax.pmean(lb, a)
                z = jax.lax.pmean(z, a)
        aux = {"load_balance": lb * m.load_balance_loss,
               "router_z": z * m.router_z_loss}
    return out, aux


def moe_forward(params: dict, cfg, x: jax.Array, ctx,
                with_aux: bool = False) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (B, S, d). Requires ctx.mesh active-compatible specs."""
    b, s, d = x.shape
    mesh = ctx.mesh
    tp = ctx.tp_size
    n_experts = params["router"]["w"].shape[-1]   # shape-derived (pruning)
    assert n_experts % tp == 0, (n_experts, tp)
    e_local = n_experts // tp

    dp = ctx.dp_size if ctx.batch_sharded else 1
    n_local = (b // dp) * s
    capacity = _capacity(n_local, cfg, no_drop=ctx.moe_no_drop)

    bspec = ctx.batch_spec()[0]
    x_spec = P(bspec, None, None)

    # per-expert specs (expert axis prepended, sharded over the model axis);
    # a QuantizedLinear node gets a spec node of the same type/metadata
    def ew(p):
        if isinstance(p, L.QuantizedLinear):
            return L.QuantizedLinear(w_q=P(ctx.model_axis, None, None),
                                     scale=P(ctx.model_axis, None),
                                     bits=p.bits)
        return {"w": P(ctx.model_axis, None, None)}
    param_specs = {"router": {"w": P(None, None), "b": P(None)},
                   "gate": ew(params["gate"]), "up": ew(params["up"]),
                   "down": ew(params["down"])}

    def body(xl, pl):
        xf = xl.reshape(-1, d)
        idx = jax.lax.axis_index(ctx.model_axis)
        out, aux = _moe_local(
            xf, pl, cfg, idx * e_local, e_local, capacity,
            ctx.data_axes if ctx.batch_sharded else (), ctx.model_axis,
            with_aux)
        return out.reshape(xl.shape).astype(L.COMPUTE_DTYPE), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, param_specs),
        out_specs=(x_spec, {"load_balance": P(), "router_z": P()} if with_aux
                   else {}),
        check_rep=False,
    )
    out, aux = fn(x, params)
    return out, aux
