"""Shared building blocks: norms, rotary embeddings, (possibly quantized) dense.

Parameter convention
--------------------
A linear layer's params are either
  full precision : {"w": (in, out) bf16/f32}
  HQP-quantized  : a ``repro.compress.QuantizedLinear`` pytree node
``dense()`` dispatches on *type*, so the same model code runs both the FP
baseline and the HQP INT8 artifact — quantization is a parameter transform,
not a model rewrite. This mirrors the paper's "output is a standard model"
property; see DESIGN.md §Compression-artifact.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compress.qtypes import (QuantizedLinear, linear_bytes,
                                   linear_kernel, out_features)
from repro.kernels import ops as kops

# re-exported for model code that types against the layers namespace
__all__ = ["QuantizedLinear", "linear_bytes", "linear_kernel",
           "out_features"]

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------- init utils
def he_init(key, shape, dtype=jnp.float32, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * (2.0 / fan) ** 0.5).astype(dtype)


def linear_init(key, d_in, d_out, dtype=COMPUTE_DTYPE):
    return {"w": he_init(key, (d_in, d_out), dtype)}


# ---------------------------------------------------------------- dense
def dense(x: jax.Array, p) -> jax.Array:
    """Matmul dispatch: FP weight dict, or a typed ``QuantizedLinear``.

    The INT8 path intentionally keeps the weight int8 in HLO (bytes halve in
    the roofline memory term); dequant is folded into the matmul epilogue by
    scaling the int32/f32 accumulator — never materializing an FP weight.
    Which kernel runs is the execution backend's choice
    (``kernels.backend``): fused Pallas on TPU, XLA-fused jnp elsewhere.
    """
    if isinstance(p, QuantizedLinear):
        return kops.int8_matmul(x, p.w_q, p.scale)
    w = p["w"]
    return jnp.dot(x.astype(COMPUTE_DTYPE), w.astype(COMPUTE_DTYPE))


def dense_param_bytes(p) -> int:
    return linear_bytes(p)


# ---------------------------------------------------------------- norms
def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(x: jax.Array, p: dict, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["g"]
    return y.astype(COMPUTE_DTYPE)


def l2norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3 style), no learned scale on the head axis."""
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
            ).astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------- embedding
def embed_init(key, vocab: int, d: int, dtype=COMPUTE_DTYPE):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed_lookup(p: dict, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens].astype(COMPUTE_DTYPE)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    """Logits in f32 for a stable softmax/loss."""
    return jnp.dot(x.astype(COMPUTE_DTYPE), p["table"].T.astype(COMPUTE_DTYPE)
                   ).astype(jnp.float32)


# ---------------------------------------------------------------- MLP (SwiGLU)
def mlp_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff),
        "up": linear_init(k2, d_model, d_ff),
        "down": linear_init(k3, d_ff, d_model),
    }


def mlp(x: jax.Array, p: dict) -> jax.Array:
    return dense(jax.nn.silu(dense(x, p["gate"])) * dense(x, p["up"]), p["down"])
