"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, strictly sequential recurrence), after arXiv:2405.04517.

mLSTM uses the stabilized chunkwise form (log-space gate cumulants, running
max stabilizer `m`, carried (C, n, m) inter-chunk state) — the TPU-friendly
adaptation: intra-chunk work is dense (c x c) MXU matmuls, inter-chunk work is
a short lax.scan. sLSTM keeps its nonlinear h->gate recurrence, so it is a
per-step lax.scan (no parallel form exists); its block-diagonal recurrent
matrices keep the per-step matmuls head-local.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG = -1e30


# ===================================================================== mLSTM
def mlstm_init(key, cfg) -> dict:
    x = cfg.xlstm
    d = cfg.d_model
    d_in = int(x.proj_factor_mlstm * d)
    h = cfg.n_heads
    hd = d_in // h
    ks = jax.random.split(key, 7)
    blk = lambda k: (jax.random.normal(k, (h, hd, hd)) * hd ** -0.5
                     ).astype(L.COMPUTE_DTYPE)
    return {
        "in_proj": L.linear_init(ks[0], d, 2 * d_in),
        "wq": blk(ks[1]), "wk": blk(ks[2]), "wv": blk(ks[3]),
        "w_i": {"w": L.he_init(ks[4], (d_in, h), jnp.float32),
                "b": jnp.zeros((h,), jnp.float32)},
        "w_f": {"w": L.he_init(ks[5], (d_in, h), jnp.float32),
                "b": jnp.full((h,), 3.0, jnp.float32)},   # open forget gates
        "norm": {"g": jnp.ones((d_in,), jnp.float32)},
        "out_proj": L.linear_init(ks[6], d_in, d),
    }


def _mlstm_chunk(q, k, v, i_pre, log_f, carry):
    """One chunk, vectorized over (B, H).

    q,k,v: (B,H,c,hd); i_pre, log_f: (B,H,c); carry = (C (B,H,hd,hd),
    n (B,H,hd), m (B,H)). Returns y (B,H,c,hd), new carry."""
    bsz, h, c, hd = q.shape
    cmat, n, m = carry
    b = jnp.cumsum(log_f, axis=-1)                        # (B,H,c)
    total_f = b[..., -1]

    # intra-chunk log decay: L[t,s] = b_t - b_s + i_s  (s <= t)
    lmat = b[..., :, None] - b[..., None, :] + i_pre[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    lmat = jnp.where(tri, lmat, NEG)
    m_intra = jnp.max(lmat, axis=-1)                      # (B,H,c)
    m_inter = m[..., None] + b                            # (B,H,c)
    m_t = jnp.maximum(m_inter, m_intra)

    p = jnp.exp(lmat - m_t[..., None])                    # (B,H,c,c)
    e_inter = jnp.exp(m_inter - m_t)                      # (B,H,c)

    scores = jnp.einsum("bhtd,bhsd->bhts", q, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    sp = scores * p
    qc = jnp.einsum("bhtd,bhde->bhte", q,
                    cmat.astype(L.COMPUTE_DTYPE),
                    preferred_element_type=jnp.float32)
    y_num = (qc * e_inter[..., None]
             + jnp.einsum("bhts,bhsd->bhtd", sp.astype(L.COMPUTE_DTYPE), v,
                          preferred_element_type=jnp.float32))
    n_num = (jnp.einsum("bhtd,bhd->bht", q, n.astype(L.COMPUTE_DTYPE),
                        preferred_element_type=jnp.float32) * e_inter
             + jnp.sum(sp, axis=-1))
    denom = jnp.maximum(jnp.abs(n_num), jnp.exp(-m_t))[..., None]
    y = y_num / denom

    # carry update
    m_next = jnp.maximum(m + total_f,
                         jnp.max(total_f[..., None] - b + i_pre, axis=-1))
    decay_old = jnp.exp(m + total_f - m_next)             # (B,H)
    w_s = jnp.exp(total_f[..., None] - b + i_pre - m_next[..., None])  # (B,H,c)
    kw = k.astype(jnp.float32) * w_s[..., None] * hd ** -0.5
    c_new = (cmat * decay_old[..., None, None]
             + jnp.einsum("bhsd,bhse->bhde", kw, v.astype(jnp.float32)))
    n_new = n * decay_old[..., None] + jnp.sum(kw, axis=-2)
    return y, (c_new, n_new, m_next)


def _heads(x, h):
    b, s, d = x.shape
    return jnp.moveaxis(x.reshape(b, s, h, d // h), 2, 1)  # (B,H,S,hd)


def mlstm_forward(p: dict, cfg, x: jax.Array,
                  state: Optional[Tuple] = None) -> Tuple[jax.Array, Optional[Tuple]]:
    """x: (B, S, d). state (decode) = (C, n, m)."""
    xcfg = cfg.xlstm
    bsz, seq, d = x.shape
    hd = p["wq"].shape[-1]                        # per-head width (fixed)
    d_in = L.out_features(p["in_proj"]) // 2
    h = d_in // hd                                # shape-derived (pruning)
    xz = L.dense(x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)

    xh = _heads(xin, h)                                   # (B,H,S,hd)
    q = jnp.einsum("bhsd,hde->bhse", xh, p["wq"])
    k = jnp.einsum("bhsd,hde->bhse", xh, p["wk"])
    v = jnp.einsum("bhsd,hde->bhse", xh, p["wv"])
    xf = xin.astype(jnp.float32)
    i_pre = (jnp.einsum("bsd,dh->bsh", xf, p["w_i"]["w"]) + p["w_i"]["b"])
    f_pre = (jnp.einsum("bsd,dh->bsh", xf, p["w_f"]["w"]) + p["w_f"]["b"])
    i_pre = jnp.moveaxis(i_pre, -1, 1)                    # (B,H,S)
    log_f = jnp.moveaxis(jax.nn.log_sigmoid(f_pre), -1, 1)

    if state is None:
        state = (jnp.zeros((bsz, h, hd, hd), jnp.float32),
                 jnp.zeros((bsz, h, hd), jnp.float32),
                 jnp.zeros((bsz, h), jnp.float32))
    chunk = min(xcfg.chunk, seq)
    assert seq % chunk == 0
    nc = seq // chunk

    def r(t):  # (B,H,S,...) -> (nc,B,H,c,...)
        return jnp.moveaxis(
            t.reshape(bsz, h, nc, chunk, *t.shape[3:]), 2, 0)

    def step(carry, xs):
        qc, kc, vc, ic, fc = xs
        y, carry = _mlstm_chunk(qc, kc, vc, ic, fc, carry)
        return carry, y

    new_state, ys = jax.lax.scan(step, state, (r(q), r(k), r(v), r(i_pre), r(log_f)))
    y = jnp.moveaxis(ys, 0, 2).reshape(bsz, h, seq, hd)   # (B,H,S,hd)
    y = jnp.moveaxis(y, 1, 2)                             # (B,S,H,hd)
    # per-head norm (multi-head layernorm a la xLSTM): keeps masked-prune
    # evaluation identical to physical compaction
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps)).reshape(bsz, seq, d_in)
    y = (y * p["norm"]["g"]).astype(L.COMPUTE_DTYPE)
    out = L.dense(y * jax.nn.silu(z), p["out_proj"])
    return out, (new_state if seq == 1 or state is not None else None)


def init_mlstm_state(batch: int, cfg, d_in: Optional[int] = None) -> Tuple:
    """``d_in`` override: width of an HQP-compacted block (head width hd is
    fixed under head pruning; the head count shrinks)."""
    hd = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model) // cfg.n_heads
    if d_in is None:
        d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    h = d_in // hd
    return (jnp.zeros((batch, h, hd, hd), jnp.float32),
            jnp.zeros((batch, h, hd), jnp.float32),
            jnp.zeros((batch, h), jnp.float32))


# ===================================================================== sLSTM
def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    d_up = int(cfg.xlstm.proj_factor_slstm * d)
    ks = jax.random.split(key, 12)
    wg = lambda k: L.he_init(k, (d, d), jnp.float32)
    rg = lambda k: (jax.random.normal(k, (h, hd, hd)) * hd ** -0.5
                    ).astype(jnp.float32)
    return {
        "wz": wg(ks[0]), "wi": wg(ks[1]), "wf": wg(ks[2]), "wo": wg(ks[3]),
        "rz": rg(ks[4]), "ri": rg(ks[5]), "rf": rg(ks[6]), "ro": rg(ks[7]),
        "b_z": jnp.zeros((d,)), "b_i": jnp.zeros((d,)),
        "b_f": jnp.full((d,), 3.0), "b_o": jnp.zeros((d,)),
        "norm": {"g": jnp.ones((d,), jnp.float32)},
        "up": L.linear_init(ks[8], d, 2 * d_up),
        "down": L.linear_init(ks[9], d_up, d),
    }


def _slstm_step(p, h_heads, x_gates, state, n_heads):
    """One timestep. x_gates: precomputed W*x (B, 4, d). state: (h,c,n,m)."""
    h, c, n, m = state
    b, d = h.shape
    hh = h.reshape(b, n_heads, d // n_heads)

    def rec(r):
        return jnp.einsum("bhd,hde->bhe", hh.astype(jnp.float32), r
                          ).reshape(b, d)

    z = jnp.tanh(x_gates[:, 0] + rec(p["rz"]) + p["b_z"])
    i_pre = x_gates[:, 1] + rec(p["ri"]) + p["b_i"]
    f_pre = x_gates[:, 2] + rec(p["rf"]) + p["b_f"]
    o = jax.nn.sigmoid(x_gates[:, 3] + rec(p["ro"]) + p["b_o"])
    m_new = jnp.maximum(f_pre + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(f_pre + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_forward(p: dict, cfg, x: jax.Array,
                  state: Optional[Tuple] = None) -> Tuple[jax.Array, Optional[Tuple]]:
    """x: (B, S, d). Sequential scan over S (no parallel form)."""
    b, seq, d = x.shape
    h = cfg.n_heads
    xf = x.astype(jnp.float32)
    gates = jnp.stack([xf @ p["wz"], xf @ p["wi"],
                       xf @ p["wf"], xf @ p["wo"]], axis=2)  # (B,S,4,d)
    decode = state is not None
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = (zeros, zeros, zeros, zeros)

    def step(st, g):
        st = _slstm_step(p, None, g, st, h)
        return st, st[0]

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(gates, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)                            # (B,S,d)
    y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    # gated up/down MLP (proj factor 4/3)
    u, g = jnp.split(L.dense(y, p["up"]), 2, axis=-1)
    out = L.dense(u * jax.nn.silu(g), p["down"])
    return out, (state if decode else None)


def init_slstm_state(batch: int, cfg) -> Tuple:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, z)
