"""Unified decoder LM over heterogeneous block patterns.

One model definition covers all ten assigned architectures: dense GQA
transformers, MoE, Mamba-hybrid (jamba), and xLSTM stacks. The layer pattern
(cfg.pattern x MoE placement) is reduced to its minimal period ``p``; params
are stacked over the ``n_layers/p`` repetitions and the stack is driven by
``lax.scan`` (HLO stays O(p) regardless of depth — compile-time and HLO-size
are depth-independent, which matters at 72 layers x 512 devices).

Decode state is a per-period-position pytree stacked over groups, scanned
jointly with the params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as SSM
from repro.models import xlstm as X
from repro.sharding.ctx import RunContext, default_ctx


# ------------------------------------------------------------------ pattern
def layer_specs(cfg) -> Tuple[Tuple[str, bool], ...]:
    return tuple((kind, cfg.is_moe_layer(i)) for i, kind in enumerate(cfg.pattern))


def pattern_period(cfg) -> int:
    spec = layer_specs(cfg)
    n = len(spec)
    for p in range(1, n + 1):
        if n % p == 0 and all(spec[i] == spec[i % p] for i in range(n)):
            return p
    return n


VOCAB_PAD = 256


def padded_vocab(cfg) -> int:
    """Vocab rounded up so the embedding/unembedding tables shard evenly on
    any mesh axis (granite's 49155 is odd: unpadded it falls back to a fully
    replicated table — an 806 MB f32 read per decode step on the 16x16 mesh;
    EXPERIMENTS.md §Perf granite iteration 2). Padding logits are masked to
    -inf, so the distribution over real tokens is unchanged."""
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


# ------------------------------------------------------------------ init
def _block_init(key, cfg, kind: str, is_moe: bool) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.rmsnorm_init(cfg.d_model)}
    if kind == "attn":
        p["attn"] = A.attention_init(ks[0], cfg)
    elif kind == "mamba":
        p["mamba"] = SSM.mamba_init(ks[0], cfg)
    elif kind == "mlstm":
        p["mlstm"] = X.mlstm_init(ks[0], cfg)
        return p
    elif kind == "slstm":
        p["slstm"] = X.slstm_init(ks[0], cfg)
        return p
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
        if is_moe:
            p["moe"] = M.moe_init(ks[1], cfg)
            if cfg.moe.dense_residual:
                p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg) -> dict:
    period = pattern_period(cfg)
    groups = cfg.n_layers // period
    spec = layer_specs(cfg)
    k_emb, k_fr, k_blocks = jax.random.split(key, 3)
    v_pad = padded_vocab(cfg)
    params: Dict[str, Any] = {"embed": L.embed_init(k_emb, v_pad,
                                                    cfg.d_model)}
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(
            jax.random.fold_in(k_emb, 1), v_pad, cfg.d_model)
    if cfg.frontend.kind != "none":
        params["frontend"] = L.linear_init(k_fr, cfg.d_model, cfg.d_model)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    per_layer = [_block_init(layer_keys[i], cfg, *spec[i])
                 for i in range(cfg.n_layers)]
    blocks = []
    for j in range(period):
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[per_layer[g * period + j] for g in range(groups)])
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    params["final_norm"] = L.rmsnorm_init(cfg.d_model)
    return params


# ------------------------------------------------------------------ blocks
def _ffn_part(p: dict, cfg, x, is_moe: bool, ctx, with_aux: bool):
    if cfg.d_ff <= 0:
        return x, {}
    h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
    aux = {}
    if is_moe:
        out, aux = M.moe_forward(p["moe"], cfg, h, ctx, with_aux)
        if cfg.moe.dense_residual:
            out = out + L.mlp(h, p["mlp"])
    else:
        out = L.mlp(h, p["mlp"])
    return x + out, aux


def _block_forward(kind: str, is_moe: bool, p: dict, cfg, x, positions, ctx,
                   cache=None, cur_len=None, with_aux: bool = False,
                   window=None, route=None, pages=None):
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    new_cache = cache
    if kind == "attn":
        a, new_cache = A.attention_forward(p["attn"], cfg, h, positions,
                                           cache, cur_len, ctx, window,
                                           route, pages)
        x = x + a
        x, aux = _ffn_part(p, cfg, x, is_moe, ctx, with_aux)
    elif kind == "mamba":
        m_out, new_cache = SSM.mamba_forward(p["mamba"], cfg, h, cache)
        x = x + m_out
        x, aux = _ffn_part(p, cfg, x, is_moe, ctx, with_aux)
    elif kind == "mlstm":
        y, new_cache = X.mlstm_forward(p["mlstm"], cfg, h, cache)
        x, aux = x + y, {}
    elif kind == "slstm":
        y, new_cache = X.slstm_forward(p["slstm"], cfg, h, cache)
        x, aux = x + y, {}
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def _shard_x(x, ctx: RunContext):
    if ctx.mesh.size > 1:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(
                ctx.mesh, P(ctx.batch_spec()[0], None, None)))
    return x


# ------------------------------------------------------------------ forward
def forward(params: dict, cfg, batch: dict, ctx: Optional[RunContext] = None,
            with_aux: bool = False) -> Tuple[jax.Array, dict]:
    """Returns (final hidden states (B, S, d), aux losses)."""
    ctx = ctx or default_ctx()
    x = L.embed_lookup(params["embed"], batch["tokens"])
    if cfg.frontend.kind != "none":
        fr = L.dense(batch["embeds"].astype(L.COMPUTE_DTYPE),
                     params["frontend"])
        x = jnp.concatenate([fr, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    period = pattern_period(cfg)
    spec = layer_specs(cfg)[:period]
    has_moe = any(m for _, m in spec) and with_aux
    aux0 = ({"load_balance": jnp.zeros((), jnp.float32),
             "router_z": jnp.zeros((), jnp.float32)} if has_moe else {})

    def group(carry, block_params):
        x, aux = carry
        x = _shard_x(x, ctx)
        for j, (kind, is_moe) in enumerate(spec):
            x, _, aux_j = _block_forward(kind, is_moe, block_params[j], cfg,
                                         x, positions, ctx,
                                         with_aux=with_aux)
            if has_moe and aux_j:
                aux = {k: aux[k] + aux_j[k] for k in aux}
        return (x, aux), None

    body = group
    if ctx.remat:
        body = jax.checkpoint(
            group, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def unembed_params(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


def logits_fn(params, cfg, hidden) -> jax.Array:
    logits = L.unembed(unembed_params(params, cfg), hidden)
    return _mask_pad(logits, cfg)


def _mask_pad(logits, cfg):
    v_pad = logits.shape[-1]
    if v_pad == cfg.vocab_size:
        return logits
    mask = jnp.arange(v_pad) < cfg.vocab_size
    return jnp.where(mask, logits, -1e30)


def loss_fn(params: dict, cfg, batch: dict,
            ctx: Optional[RunContext] = None, with_aux: bool = True,
            ce_chunk: int = 512) -> Tuple[jax.Array, dict]:
    """Next-token CE over text positions, sequence-chunked so the (B,S,V)
    logits tensor is never materialized (peak is (B, ce_chunk, V))."""
    ctx = ctx or default_ctx()
    hidden, aux = forward(params, cfg, batch, ctx, with_aux)
    n_fr = cfg.frontend.n_embeds if cfg.frontend.kind != "none" else 0
    tokens = batch["tokens"]
    b, st = tokens.shape
    # hidden positions n_fr..n_fr+st-2 predict tokens 1..st-1
    h = hidden[:, n_fr:n_fr + st - 1]
    targets = tokens[:, 1:]
    n_tok = h.shape[1]
    ce_chunk = min(ce_chunk, n_tok)
    n_chunks = n_tok // ce_chunk
    rem = n_tok - n_chunks * ce_chunk
    ue = unembed_params(params, cfg)

    def ce(hc, tc):
        lg = _mask_pad(L.unembed(ue, hc), cfg)           # (B, c, V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def step(acc, xs):
        hc, tc = xs
        return acc + ce(hc, tc), None

    hs = jnp.moveaxis(
        h[:, :n_chunks * ce_chunk].reshape(b, n_chunks, ce_chunk, -1), 1, 0)
    ts = jnp.moveaxis(
        targets[:, :n_chunks * ce_chunk].reshape(b, n_chunks, ce_chunk), 1, 0)
    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ts))
    if rem:
        total = total + ce(h[:, n_chunks * ce_chunk:],
                           targets[:, n_chunks * ce_chunk:])
    loss = total / (b * n_tok)
    for v in aux.values():
        loss = loss + v
    return loss, aux


# ------------------------------------------------------------------ decode
def init_decode_state(cfg, batch: int, max_seq: int,
                      ctx: Optional[RunContext] = None,
                      params: Optional[dict] = None,
                      per_slot_pos: bool = False,
                      kv_pages: Optional[Tuple[int, int]] = None) -> dict:
    """Stacked per-period-position caches + current length.

    ``per_slot_pos=True`` makes ``pos`` a (batch,) vector — the layout the
    continuous-batching engine uses, where every batch row ("slot") advances
    independently (``serving.state_pool`` owns slot gather/scatter).

    When ``params`` is given, per-position cache widths (KV heads, Mamba
    channels, mLSTM heads) derive from the param shapes instead of the
    config, so HQP-compacted artifacts — which physically shrank those axes
    — serve without a config rewrite. Compacted stacked families are
    shape-uniform across the layer stack, so one width per period position
    suffices.

    ``kv_pages=(total_pages, page_size)`` switches the KV caches to the
    PAGED arena layout: attention leaves become (total_pages, page_size,
    Hkv, hd) with NO batch/slot axis (the arena is shared through per-slot
    page tables the caller owns — ``serving.state_pool``), while recurrent
    Mamba/xLSTM leaves keep their per-slot batch axis (recurrent state is
    O(1) per slot; only position-indexed KV pages)."""
    ctx = ctx or default_ctx()
    period = pattern_period(cfg)
    groups = cfg.n_layers // period
    spec = layer_specs(cfg)[:period]
    hd = cfg.resolved_head_dim

    def stack(make):
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[make() for _ in range(groups)])

    def blk(j):
        return params["blocks"][j] if params is not None else None

    caches = []
    for j, (kind, _) in enumerate(spec):
        if kind == "attn":
            n_kv = (L.out_features(blk(j)["attn"]["wk"]) // hd
                    if params is not None else cfg.n_kv_heads)
            kv_b, kv_s = kv_pages if kv_pages is not None else (batch,
                                                                max_seq)
            caches.append(stack(lambda: A.init_kv_cache(
                kv_b, kv_s, n_kv, hd, ctx.quantized_kv,
                paged=kv_pages is not None)))
        elif kind == "mamba":
            d_in = (blk(j)["mamba"]["conv_w"].shape[-1]
                    if params is not None else None)
            caches.append(stack(
                lambda: SSM.init_mamba_state(batch, cfg, d_in=d_in)))
        elif kind == "mlstm":
            d_in = (L.out_features(blk(j)["mlstm"]["in_proj"]) // 2
                    if params is not None else None)
            caches.append(stack(
                lambda: X.init_mlstm_state(batch, cfg, d_in=d_in)))
        elif kind == "slstm":
            caches.append(stack(lambda: X.init_slstm_state(batch, cfg)))
    pos = (jnp.zeros((batch,), jnp.int32) if per_slot_pos
           else jnp.zeros((), jnp.int32))
    return {"caches": tuple(caches), "pos": pos}


def decode_step(params: dict, cfg, state: dict, tokens: jax.Array,
                ctx: Optional[RunContext] = None,
                embeds: Optional[jax.Array] = None,
                window: Optional[int] = None,
                route: Optional[str] = None) -> Tuple[jax.Array, dict]:
    """tokens: (B, S_new) (S_new=1 for decode, >1 for cache-filling prefill).

    ``state["pos"]`` is a scalar (whole batch at one position — the serial
    serve path) or a (B,) vector of per-slot positions (the continuous-
    batching engine, where each slot is mid-way through its own request).
    With a vector pos, rope positions and KV-cache writes/masks are all
    slot-indexed; the recurrent (Mamba/xLSTM) states are position-free and
    need no change.

    ``embeds``: optional precomputed frontend embeddings, prepended during
    prefill (VLM patches / audio frames).

    ``window``: STATIC visible-window bound on KV-cache attends (host-side
    callers bucket ``max(pos)+S_new`` up to a block multiple — the engine's
    length-aware path); None attends the whole ``max_seq`` buffer. Windowed
    and full attends are bit-identical (masked positions contribute exact
    zeros); jitted callers must mark ``window`` static.

    ``route``: STATIC attend route for the KV attend — ``"prefill"`` |
    ``"decode"`` (None infers: S_new == 1 -> decode, else prefill). Chunked-
    prefill callers (the engine) pass ``route="prefill"`` explicitly so a
    1-token tail chunk stays on the ``prefill_attention`` primitive instead
    of being shape-inferred onto the decode kernel — see
    ``attention_forward``. Returns (logits, new state).

    ``state["pages"]`` (B, max_pages) int32, when present, marks the KV
    caches as PAGED arenas (``init_decode_state(kv_pages=...)``): every KV
    write and attend indirects through the per-row page table. The table is
    an INPUT only — the returned state is always ``{"caches", "pos"}``;
    callers that page re-attach the table they own on the next call
    (``serving.engine`` redirects inactive rows to the trash page between
    dispatches, which a pass-through here would silently undo)."""
    ctx = ctx or default_ctx()
    x = L.embed_lookup(params["embed"], tokens)
    if embeds is not None and cfg.frontend.kind != "none":
        fr = L.dense(embeds.astype(L.COMPUTE_DTYPE), params["frontend"])
        x = jnp.concatenate([fr, x], axis=1)
    b, s, _ = x.shape
    cur = state["pos"]
    pages = state.get("pages")
    positions = (cur + jnp.arange(s) if jnp.ndim(cur) == 0
                 else cur[:, None] + jnp.arange(s)[None, :])
    period = pattern_period(cfg)
    spec = layer_specs(cfg)[:period]

    def group(x, xs):
        block_params, caches = xs
        x = _shard_x(x, ctx)
        new_caches = []
        for j, (kind, is_moe) in enumerate(spec):
            x, nc, _ = _block_forward(kind, is_moe, block_params[j], cfg, x,
                                      positions, ctx, caches[j], cur,
                                      window=window, route=route,
                                      pages=pages)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(group, x,
                                 (params["blocks"], state["caches"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)
    return logits, {"caches": new_caches, "pos": cur + s}


def verify_step(params: dict, cfg, state: dict, tokens: jax.Array,
                ctx: Optional[RunContext] = None,
                window: Optional[int] = None) -> Tuple[jax.Array, dict]:
    """Multi-position verification scoring for speculative decoding.

    ``tokens`` is the (B, K+1) candidate chunk ``[t0, d1..dK]`` — the last
    accepted token followed by the drafter's K proposals. One
    ``route="prefill"`` pass scores ALL K+1 positions: ``logits[:, i]`` is
    the verifier's next-token distribution after consuming ``tokens[:, i]``,
    i.e. the target distribution draft ``d_{i+1}`` is judged against (and
    ``logits[:, K]`` is the bonus-token distribution when every draft
    accepts). Because the prefill route shares the serial decode route's
    absolute-position causal semantics (DESIGN.md §10), position ``i`` of
    this chunk is bit-identical to what a serial one-token-at-a-time decode
    of the same prefix would produce — the hinge of the speculative greedy
    token-identity guarantee.

    The returned state has advanced ``pos`` by K+1 and written KV for every
    candidate; the caller (``serving.speculative``) rolls ``pos`` back to
    the accepted length — stale KV past the rolled-back ``pos`` is masked
    by the absolute causal limit of every later attend and overwritten
    before it can become visible, exactly like slot reuse in the pool."""
    return decode_step(params, cfg, state, tokens, ctx, window=window,
                       route="prefill")
