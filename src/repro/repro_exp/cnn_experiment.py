"""Paper-faithful HQP reproduction on ResNet-18 / MobileNetV3-S (Tables I/II).

Pipeline per architecture:
  1. train the CNN on the deterministic synthetic dataset to a solid baseline;
  2. Fisher pass over D_calib (one backward pass, §II-B);
  3. methods:
       Q8-only  — per-tensor weight fake-quant + KL-calibrated activation quant
       P50-only — L1-magnitude structural pruning at fixed θ=50% (no guarantee)
       HQP      — Algorithm 1 conditional prune (Δ_ax=1.5%) → robust PTQ
  4. metrics: Top-1 accuracy drop (real, on the held-out val set), model size
     (INT8 storage accounting), measured CPU latency of the *compacted* model,
     and modeled edge latency (roofline: FLOPs/peak + bytes/bw, INT8 at 2x
     MXU rate / half weight bytes) — the Jetson+TensorRT measurement of the
     paper has no CPU-container equivalent, so speedup is reported on the
     declared TPU-edge model (DESIGN.md §2 hardware adaptation).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_cnn_config
from repro.core import calibration as calib
from repro.core import pipeline as pipe
from repro.core import pruning as pr
from repro.core import sensitivity as sens
from repro.data.synthetic import SyntheticImages
from repro.models import cnn
from repro.roofline.hardware import TPU_V5E


# ------------------------------------------------------------------ training
def ce_loss(cfg, variables, batch, train=True):
    logits, new_stats = cnn.cnn_apply(cfg, variables, batch["image"], train)
    labels = batch["label"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold), new_stats


def train_cnn(cfg, data: SyntheticImages, steps: int = 400,
              batch_size: int = 128, lr: float = 0.2, log=print):
    variables = cnn.cnn_init(jax.random.PRNGKey(0), cfg)
    velocity = jax.tree.map(jnp.zeros_like, variables["params"])

    @jax.jit
    def step_fn(variables, velocity, batch, lr_t):
        (l, new_stats), grads = jax.value_and_grad(
            lambda p: ce_loss(cfg, {"params": p, "stats": variables["stats"]},
                              batch), has_aux=True)(variables["params"])
        velocity = jax.tree.map(lambda v, g: 0.9 * v + g, velocity, grads)
        params = jax.tree.map(lambda p, v: p - lr_t * v,
                              variables["params"], velocity)
        return {"params": params, "stats": new_stats}, velocity, l

    it = data.batches(batch_size, seed=1, epochs=1000)
    t0 = time.time()
    for i in range(steps):
        batch = next(it)
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * i / steps))   # cosine decay
        variables, velocity, l = step_fn(variables, velocity, batch,
                                         jnp.float32(lr_t))
        if i % 100 == 0 or i == steps - 1:
            log(f"  [train {cfg.arch}] step {i} loss={float(l):.4f} "
                f"({time.time()-t0:.0f}s)")
    return variables


def make_eval_fn(cfg, val: SyntheticImages, batch_size: int = 250,
                 actq: Optional[calib.ActQ] = None) -> Callable:
    apply = jax.jit(functools.partial(_apply_eval, cfg, actq))

    def eval_fn(variables) -> float:
        correct = total = 0
        for b in val.batches(batch_size):
            pred = apply(variables, b["image"])
            correct += int(np.sum(np.asarray(pred) == b["label"]))
            total += len(b["label"])
        return correct / total
    return eval_fn


def _apply_eval(cfg, actq, variables, image):
    logits, _ = cnn.cnn_apply(cfg, variables, image, train=False, actq=actq)
    return jnp.argmax(logits, axis=-1)


# ------------------------------------------------------------------ fisher
def fisher_for(cfg, variables, calib_data: SyntheticImages,
               batch_size: int = 100):
    @jax.jit
    def grad_fn(params, batch):
        return jax.grad(lambda p: ce_loss(
            cfg, {"params": p, "stats": variables["stats"]}, batch,
            train=False)[0])(params)

    sq, _ = sens.fisher_diag(
        lambda p, b: grad_fn(p, b), variables["params"],
        calib_data.batches(batch_size))
    # wrap to full-variables layout (specs address ("params", ...))
    return {"params": sq, "stats": jax.tree.map(jnp.zeros_like,
                                                variables["stats"])}


# ------------------------------------------------------------------ PTQ
def calibrate_activations(cfg, variables, calib_data: SyntheticImages,
                          method: str = "kl", n_batches: int = 4) -> calib.ActQ:
    actq = calib.ActQ(mode="amax", method=method)
    batches = list(calib_data.batches(100))[:n_batches]
    for b in batches:                      # pass 1: ranges
        cnn.cnn_apply(cfg, variables, b["image"], train=False, actq=actq)
    actq.mode = "hist"
    for b in batches:                      # pass 2: histograms
        cnn.cnn_apply(cfg, variables, b["image"], train=False, actq=actq)
    return actq.finalize()


# ------------------------------------------------------------------ latency
def measured_latency_ms(cfg, variables, batch: int = 64, iters: int = 30,
                        image_size: int = 32) -> float:
    x = jnp.asarray(np.random.RandomState(0).randn(
        batch, image_size, image_size, 3).astype(np.float32))
    f = jax.jit(lambda v, x: cnn.cnn_apply(cfg, v, x, train=False)[0])
    f(variables, x).block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f(variables, x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1000)


def modeled_latency_ms(cfg, variables, int8: bool, batch: int = 64,
                       image_size: int = 32) -> float:
    """Edge roofline model: max(FLOPs/peak, bytes/bw); INT8 = 2x peak and
    half the weight bytes (per DESIGN.md hardware adaptation)."""
    x = jax.ShapeDtypeStruct((batch, image_size, image_size, 3), jnp.float32)
    compiled = jax.jit(
        lambda v, xx: cnn.cnn_apply(cfg, v, xx, train=False)[0]
    ).lower(variables, x).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    flops = float(ca.get("flops", 0))
    byts = float(ca.get("bytes accessed", 0))
    chip = TPU_V5E
    # single low-power edge chip model: scale chip peaks down uniformly; the
    # *ratios* (which determine speedup) are what matters.
    peak = chip.peak_int8 if int8 else chip.peak_bf16
    wbytes = pr.param_bytes(variables["params"])
    if int8:
        byts = byts - 0.5 * wbytes          # int8 weight stream
    t = max(flops / peak, byts / chip.hbm_bw)
    return t * 1000


# ------------------------------------------------------------------ methods
@dataclasses.dataclass
class MethodResult:
    method: str
    accuracy: float
    drop: float
    size_bytes: int
    size_reduction: float
    theta: float
    measured_ms: float
    modeled_ms: float
    compliant: bool


def run_experiment(arch: str, delta_ax: float = 0.015, train_steps: int = 400,
                   n_train: int = 6000, n_val: int = 2000, n_calib: int = 1000,
                   width: float = 0.5, log=print) -> Dict:
    cfg = dataclasses.replace(get_cnn_config(arch), width_mult=width)
    train_data = SyntheticImages(n_train, seed=0)
    val_data = SyntheticImages(n_val, seed=100)
    calib_data = SyntheticImages(n_calib, seed=200)

    log(f"[repro:{arch}] training baseline...")
    variables = train_cnn(cfg, train_data, steps=train_steps, log=log)
    eval_fn = make_eval_fn(cfg, val_data)
    a_base = eval_fn(variables)
    base_bytes = pr.param_bytes(variables["params"])
    base_measured = measured_latency_ms(cfg, variables)
    base_modeled = modeled_latency_ms(cfg, variables, int8=False)
    log(f"[repro:{arch}] baseline acc={a_base:.4f} size={base_bytes/1e6:.2f}MB"
        f" measured={base_measured:.1f}ms modeled={base_modeled*1000:.1f}us")

    specs = sens.cnn_prune_groups(cfg, variables)
    results: List[MethodResult] = []

    def add(method, acc, size_bytes, theta, meas, model):
        drop = a_base - acc
        results.append(MethodResult(
            method, acc, drop, int(size_bytes),
            1 - size_bytes / base_bytes, theta, meas, model,
            compliant=drop <= delta_ax))

    add("Baseline (FP32)", a_base, base_bytes, 0.0, base_measured,
        base_modeled)

    # ---------------- Q8-only (per-tensor PTQ, KL activations) ----------
    log(f"[repro:{arch}] Q8-only...")
    from repro.compress import compress
    art_q8 = compress(variables, cfg,
                      hqp=pipe.HQPConfig(track="fake"), log=log)
    qv = art_q8.params
    actq = calibrate_activations(cfg, qv, calib_data)
    acc_q8 = make_eval_fn(cfg, val_data, actq=actq)(qv)
    add("Quantization Only (Q8)", acc_q8, art_q8.manifest.bytes_after,
        0.0, base_measured, modeled_latency_ms(cfg, variables, int8=True))

    # ---------------- P50-only (magnitude, no constraint) ---------------
    log(f"[repro:{arch}] P50-only (L1 magnitude)...")
    mag = {"params": jax.tree.map(lambda w: jnp.square(w.astype(jnp.float32)),
                                  variables["params"]),
           "stats": jax.tree.map(jnp.zeros_like, variables["stats"])}
    ranked_mag = pr.rank_units(specs, mag)
    n50 = ranked_mag.total // 2
    p50 = pr.apply_prune_masks(variables, ranked_mag, n50)
    acc_p50 = eval_fn(p50)
    p50c = pr.compact_params(variables, ranked_mag, n50)
    add("Pruning Only (P50)", acc_p50, pr.param_bytes(p50c["params"]),
        0.5, measured_latency_ms(cfg, p50c),
        modeled_latency_ms(cfg, p50c, int8=False))

    # ---------------- HQP (Algorithm 1 -> robust PTQ) -------------------
    log(f"[repro:{arch}] HQP conditional prune (Fisher S, Δ_ax={delta_ax})...")
    sq = fisher_for(cfg, variables, calib_data)
    hqp_cfg = pipe.HQPConfig(delta_ax=delta_ax, step_frac=0.02, max_steps=60,
                             track="fake")
    art = compress(variables, cfg, sq_grads=sq, eval_fn=eval_fn, hqp=hqp_cfg,
                   specs=specs, a_baseline=a_base, log=log)
    log(art.manifest.summary())
    hqp_compact = art.params                 # compacted + fake-quantized
    actq_hqp = calibrate_activations(cfg, hqp_compact, calib_data)
    acc_hqp = make_eval_fn(cfg, val_data, actq=actq_hqp)(hqp_compact)
    add("Proposed HQP", acc_hqp, art.manifest.bytes_after,
        art.manifest.theta, measured_latency_ms(cfg, hqp_compact),
        modeled_latency_ms(cfg, hqp_compact, int8=True))

    table = {
        "arch": arch,
        "baseline_accuracy": a_base,
        "delta_ax": delta_ax,
        "rows": [dataclasses.asdict(r) for r in results],
        "speedups_modeled": {
            r.method: results[0].modeled_ms / r.modeled_ms for r in results},
        "speedups_measured": {
            r.method: results[0].measured_ms / r.measured_ms for r in results},
        "hqp_sparsity_by_family": art.manifest.theta_by_family,
        "hqp_history": art.manifest.history,
    }
    return table


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mobilenetv3s",
                    choices=["mobilenetv3s", "resnet18", "both"])
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--width", type=float, default=0.5)
    ap.add_argument("--ntrain", type=int, default=6000)
    ap.add_argument("--nval", type=int, default=2000)
    ap.add_argument("--out", default="experiments/repro")
    args = ap.parse_args()
    import pathlib
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = ["mobilenetv3s", "resnet18"] if args.arch == "both" else [args.arch]
    for arch in archs:
        table = run_experiment(arch, train_steps=args.steps, width=args.width,
                               n_train=args.ntrain, n_val=args.nval)
        (out / f"{arch}.json").write_text(json.dumps(table, indent=1))
        print(json.dumps({k: v for k, v in table.items()
                          if k not in ("hqp_history",)}, indent=1)[:2000])


if __name__ == "__main__":
    main()
