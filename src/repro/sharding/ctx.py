"""Run context: mesh + axis-name conventions threaded through model code.

Mesh axis conventions (see DESIGN.md §5):
  single-pod : ("data", "model")                16 x 16
  multi-pod  : ("pod", "data", "model")         2 x 16 x 16
DP/FSDP axes = ("pod", "data") (those present); TP/EP axis = "model".

Model code that needs explicit collectives (the shard_map'd MoE dispatch)
reads the axis names from the RunContext instead of hard-coding them, so the
same model runs on a 1x1 CPU mesh in tests and the 512-chip mesh in dry-runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class RunContext:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)   # batch / FSDP axes (incl. "pod")
    model_axis: str = "model"
    batch_sharded: bool = True               # False for global_batch < |data axes|
    quantized_kv: bool = False               # INT8 KV cache for decode
    remat: bool = True
    pure_dp: bool = False                    # no-TP archs (xLSTM): batch takes
                                             # the model axis too, params FSDP
    moe_no_drop: bool = True                 # inference: lossless MoE dispatch
                                             # (capacity covers every routed
                                             # pair, so batched prefill ==
                                             # per-token decode); the training
                                             # launcher turns this off and
                                             # lets capacity_factor drop

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.data_axes) + (self.model_axis,)

    def batch_spec(self) -> Tuple:
        """Leading-batch-dim sharding ((data axes) or replicated)."""
        if not self.batch_sharded:
            return (None,)
        if self.pure_dp:
            return (self.all_axes,)
        return (tuple(self.data_axes),)

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])


@functools.lru_cache(maxsize=1)
def default_ctx() -> RunContext:
    """1x1 mesh over the first device — used by tests/smoke runs."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return RunContext(mesh=Mesh(dev, ("data", "model")))


def make_ctx(mesh: Mesh, **kw) -> RunContext:
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    return RunContext(mesh=mesh, data_axes=data_axes, **kw)
