from repro.sharding.ctx import RunContext, default_ctx  # noqa: F401

__all__ = ["RunContext", "default_ctx"]
