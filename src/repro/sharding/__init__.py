from repro.sharding.ctx import RunContext, default_ctx  # noqa: F401
