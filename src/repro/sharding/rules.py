"""Partition rules: param/state/data PartitionSpecs from path patterns.

Conventions (DESIGN.md §5):
  TP ("model"): attention heads (wq/wk/wv out, wo in), FFN hidden, experts
  (EP), vocab. FSDP (data axes): the other big axis of every matrix, and
  optimizer state. xLSTM blocks: FSDP only (4 heads < 16-way model axis —
  documented TP underutilization).

Decode-state sharding: KV caches shard batch over data and *sequence* over
model (flash-decoding style); for global_batch=1 (long_500k) the sequence
axis takes every mesh axis.
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.ctx import RunContext

# (regex over "/"-joined path, spec builder(ctx) -> PartitionSpec)
# Stacked block leaves have a leading layer-group axis (always unsharded).
def _rules(ctx: RunContext):
    da = tuple(ctx.data_axes)
    mdl = ctx.model_axis
    if ctx.pure_dp:
        # no-TP architectures (xLSTM family): the model axis joins the FSDP
        # group; every former-TP placement collapses to None.
        da = da + (mdl,)
        mdl = None
    return [
        # embeddings: vocab x d
        (r"(embed|unembed)/table$", P(mdl, da)),
        (r"frontend/w$", P(da, mdl)),
        # attention
        (r"blocks/\d+/attn/w[qkv]/w$", P(None, da, mdl)),
        (r"blocks/\d+/attn/w[qkv]/(w_q|scale)$", P(None, da, mdl)),
        (r"blocks/\d+/attn/wo/w(_q)?$", P(None, mdl, da)),
        (r"blocks/\d+/attn/wo/scale$", P(None, da)),
        # dense mlp
        (r"blocks/\d+/mlp/(gate|up)/(w|w_q)$", P(None, da, mdl)),
        (r"blocks/\d+/mlp/(gate|up)/scale$", P(None, mdl)),
        (r"blocks/\d+/mlp/down/(w|w_q)$", P(None, mdl, da)),
        (r"blocks/\d+/mlp/down/scale$", P(None, da)),
        # MoE: experts over model (EP), FSDP on d
        (r"blocks/\d+/moe/(gate|up)/(w|w_q)$", P(None, mdl, da, None)),
        (r"blocks/\d+/moe/down/(w|w_q)$", P(None, mdl, None, da)),
        (r"blocks/\d+/moe/(gate|up|down)/scale$", P(None, mdl, None)),
        (r"blocks/\d+/moe/router/w$", P(None, da, None)),
        (r"blocks/\d+/moe/router/b$", P(None, None)),
        # mamba: d_inner over model
        (r"blocks/\d+/mamba/in_proj/(w|w_q)$", P(None, da, mdl)),
        (r"blocks/\d+/mamba/in_proj/scale$", P(None, mdl)),
        (r"blocks/\d+/mamba/conv_w$", P(None, None, mdl)),
        (r"blocks/\d+/mamba/x_proj/w$", P(None, mdl, None)),
        (r"blocks/\d+/mamba/dt_proj/w$", P(None, None, mdl)),
        (r"blocks/\d+/mamba/dt_proj/b$", P(None, mdl)),
        (r"blocks/\d+/mamba/a_log$", P(None, mdl, None)),
        (r"blocks/\d+/mamba/d_skip$", P(None, mdl)),
        (r"blocks/\d+/mamba/out_proj/(w|w_q)$", P(None, mdl, da)),
        (r"blocks/\d+/mamba/out_proj/scale$", P(None, da)),
        # xLSTM: FSDP only (heads < model-axis width)
        (r"blocks/\d+/(mlstm|slstm)/(in_proj|up|down|out_proj)/(w|w_q)$",
         P(None, da, None)),
        (r"blocks/\d+/(mlstm|slstm)/w[zifo]$", P(None, da, None)),
        # sLSTM recurrent mats stay REPLICATED: they are consumed inside the
        # per-timestep scan — FSDP-sharding them cost one all-gather per
        # TIMESTEP (x24576/step on xlstm train_4k; §Perf xlstm iteration 2).
        # mLSTM head mats are consumed once per chunk scan: FSDP is fine.
        (r"blocks/\d+/mlstm/w[qkv]$", P(None, None, da, None)),
    ]


def spec_for_path(path: str, ndim: int, shape: Tuple[int, ...],
                  ctx: RunContext) -> P:
    for pat, spec in _rules(ctx):
        if re.search(pat, path):
            if len(spec) == ndim and _divisible(shape, spec, ctx):
                return spec
            break
    return P(*([None] * ndim))


def _axis_size(ctx: RunContext, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([ctx.mesh.shape[a] for a in axes]))


def _divisible(shape, spec, ctx) -> bool:
    return all(dim % _axis_size(ctx, ax) == 0
               for dim, ax in zip(shape, spec))


def path_str(path) -> str:
    """"/"-joined tree path — the canonical key string shared by the
    sharding rules and checkpoint layouts (must stay identical: the regex
    rules and the saved-array keys both address e.g. ``.../wq/w_q``)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):        # DictKey
            parts.append(str(p.key))
        elif hasattr(p, "idx"):      # SequenceKey
            parts.append(str(p.idx))
        elif hasattr(p, "name"):     # GetAttrKey (QuantizedLinear fields)
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


_path_str = path_str


def param_specs(params: Any, ctx: RunContext) -> Any:
    """PartitionSpec pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_path(_path_str(path), leaf.ndim,
                                         leaf.shape, ctx),
        params)


def param_shardings(params: Any, ctx: RunContext) -> Any:
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                        param_specs(params, ctx),
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------ states
def opt_state_specs(params: Any, opt_state: Any, ctx: RunContext) -> Any:
    """Optimizer-state specs mirror the param spec exactly: fp32 moments take
    it verbatim; the int8 codec's q is param-shaped (same spec) and its
    per-row scale drops the trailing axis. Mirroring is load-bearing — any
    layout mismatch makes XLA reconcile with full-tensor gathers inside the
    update (arctic-480b: 12x 625 GB f32 all-gathers; EXPERIMENTS.md §Perf)."""
    pspecs = param_specs(params, ctx)

    def for_moment(ps, leaf_state):
        if isinstance(leaf_state, dict) and "q" in leaf_state:   # int8 codec
            return {"q": ps, "s": P(*ps[:-1]) if len(ps) else P()}
        return ps

    is_p = lambda x: isinstance(x, P)
    m_specs = jax.tree.map(for_moment, pspecs, opt_state["m"], is_leaf=is_p)
    v_specs = jax.tree.map(for_moment, pspecs, opt_state["v"], is_leaf=is_p)
    return {"step": P(), "m": m_specs, "v": v_specs}


def batch_specs(cfg, ctx: RunContext, kind: str = "train") -> Any:
    b = ctx.batch_spec()[0]
    specs = {"tokens": P(b, None)}
    if cfg.frontend.kind != "none":
        specs["embeds"] = P(b, None, None)
    return specs


def decode_state_specs(cfg, state: Any, ctx: RunContext) -> Any:
    """Specs for the stacked decode caches (leading group axis unsharded)."""
    b = ctx.batch_spec()[0]
    seq_axes = (ctx.model_axis,) if ctx.batch_sharded else (
        tuple(ctx.data_axes) + (ctx.model_axis,))

    def leaf_spec(leaf):
        nd = leaf.ndim
        if nd == 5:      # KV cache (G, B, S, Hkv, hd)
            if leaf.shape[2] % _axis_size(ctx, seq_axes) == 0:
                return P(None, b, seq_axes, None, None)
            return P(None, b, None, None, None)
        if nd == 4:      # (G,B,S,H) kv scales | (G,B,d_in,n) mamba | mlstm C
            if leaf.shape[2] % _axis_size(ctx, seq_axes) == 0:
                return P(None, b, seq_axes, None)
            return P(None, b, None, None)
        if nd >= 2:
            return P(*([None, b] + [None] * (nd - 2)))
        return P(None)

    def walk(tree):
        if isinstance(tree, dict) and "pos" in tree:
            return {"caches": jax.tree.map(leaf_spec, tree["caches"]),
                    "pos": P()}
        return jax.tree.map(leaf_spec, tree)

    return walk(state)
