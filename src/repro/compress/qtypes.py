"""Typed quantized-parameter containers (the HQP artifact's leaf types).

``QuantizedLinear`` replaces the ad-hoc ``{"w_q", "scale"}`` dicts: it is a
pytree-registered dataclass, so the whole JAX machinery (jit, vmap, scan,
shard_map, eval_shape, tree_map) treats it as a first-class node while model
code dispatches on *type* instead of sniffing dict keys. ``bits`` rides along
as static metadata — it is part of the treedef, not a traced leaf, so kernels
can specialize on it at trace time.

Path keys: flattening with ``tree_flatten_with_path`` yields ``GetAttrKey``
entries named exactly like the old dict keys (``w_q``, ``scale``), so the
sharding path-regex rules and checkpoint key layout are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass(frozen=True)
class QuantizedLinear:
    """INT8 linear weight: ``w_q`` (..., in, out) int8 + per-out-channel
    ``scale`` (..., out) f32. Leading axes (layer stack / experts) carry
    their own scales. ``x ≈ (x_q @ w_q) * x_scale * scale`` — dequant lives
    in the matmul epilogue, the FP weight is never materialized."""
    w_q: jax.Array
    scale: jax.Array
    bits: int = 8


jax.tree_util.register_dataclass(
    QuantizedLinear, data_fields=["w_q", "scale"], meta_fields=["bits"])


def is_quantized(p: Any) -> bool:
    return isinstance(p, QuantizedLinear)


def linear_kernel(p: Any) -> jax.Array:
    """The weight array of a (possibly quantized) linear — for shape
    derivation only (head counts / widths of HQP-compacted params)."""
    return p.w_q if isinstance(p, QuantizedLinear) else p["w"]


def out_features(p: Any) -> int:
    return linear_kernel(p).shape[-1]


def linear_bytes(p: Any) -> int:
    if isinstance(p, QuantizedLinear):
        return p.w_q.size * p.w_q.dtype.itemsize + p.scale.size * 4
    return p["w"].size * p["w"].dtype.itemsize
