"""Symmetric quantization — the single implementation both tracks share.

All math is JAX (jitted; the per-linear kernel is vmapped over stacked
layer/expert axes), so PTQ runs on-device with no numpy round-trips and is
``eval_shape``-traceable (the dry-run pipeline quantizes abstract params).

One epsilon convention (``EPS``) everywhere: the CNN simulated-INT8 track
(``fake_quant``) and the LM real-INT8 track (``quantize_linear``) previously
used 1e-8 vs 1e-12; both now go through ``symmetric_quantize``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compress.qtypes import QuantizedLinear

EPS = 1e-8          # amax floor: all-zero slices get scale EPS/qmax, q == 0
MIN_FAKE_SIZE = 64  # leaves below this stay FP in the simulated track


@functools.partial(jax.jit, static_argnames=("bits", "axes"))
def symmetric_quantize(w: jax.Array, bits: int = 8,
                       axes: Optional[Tuple[int, ...]] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Shared symmetric-quant core: q = clip(round(w/s), ±qmax), s = amax/qmax.

    ``axes``: reduction axes for amax (None = per-tensor). Returns (q float,
    scale with ``axes`` kept as size-1 dims); callers cast q for storage."""
    qmax = float(2 ** (bits - 1) - 1)
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, EPS) / qmax
    q = jnp.clip(jnp.round(wf / scale), -qmax, qmax)
    return q, scale


def _granularity_axes(ndim: int, granularity: str) -> Tuple[int, ...]:
    if granularity == "tensor":
        return tuple(range(ndim))
    return tuple(range(ndim - 1))        # per output channel (last axis)


def fake_quant(w: jax.Array, bits: int = 8,
               granularity: str = "tensor") -> jax.Array:
    """Dequantized-after-quantize weights (accuracy-simulation path)."""
    q, scale = symmetric_quantize(w, bits, _granularity_axes(w.ndim,
                                                             granularity))
    return (q * scale).astype(w.dtype)


def fake_quant_tree(params: Any, bits: int = 8, granularity: str = "tensor",
                    min_size: int = MIN_FAKE_SIZE) -> Any:
    """Fake-quantize every weight leaf with >= min_size elements (CNN track).

    BN params/stats and small vectors stay FP32 (TensorRT folds/keeps them)."""
    def fq(leaf):
        if leaf.ndim >= 2 and leaf.size >= min_size:
            return fake_quant(leaf, bits, granularity)
        return leaf
    return jax.tree.map(fq, params)


def quant_error(w: jax.Array, bits: int, granularity: str) -> float:
    """RMS dequantization error (sensitivity analyses / ablations)."""
    q, scale = symmetric_quantize(w, bits, _granularity_axes(w.ndim,
                                                             granularity))
    deq = q * scale
    return float(jnp.sqrt(jnp.mean(jnp.square(
        w.astype(jnp.float32) - deq))))


# ------------------------------------------------------------------ real INT8
QUANT_LINEAR_KEYS = ("wq", "wk", "wv", "wo", "gate", "up", "down",
                     "in_proj", "out_proj", "frontend")


def _quantize_linear_2d(w: jax.Array, bits: int):
    q, scale = symmetric_quantize(w, bits, axes=(0,))   # reduce the in-axis
    return q.astype(jnp.int8), scale[0].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_linear(p: Any, bits: int = 8) -> QuantizedLinear:
    """{"w": (.., in, out)} (or a bare array) -> QuantizedLinear.

    Stacked (L, in, out) and expert (L, E, in, out) layouts are handled by
    vmapping the 2D kernel over the leading axes: the scale is per-out-channel
    within each leading index."""
    w = p["w"] if isinstance(p, dict) else p
    fn = functools.partial(_quantize_linear_2d, bits=bits)
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    q, scale = fn(w)
    return QuantizedLinear(w_q=q, scale=scale, bits=bits)


def quantize_lm_params(params: Any, bits: int = 8,
                       skip: Tuple[str, ...] = ("router", "dt_proj", "x_proj"),
                       ) -> Any:
    """Walk the LM param tree; replace quantizable linears with
    ``QuantizedLinear``. Embeddings, norms, routers and the small SSM
    projections stay high-precision (standard practice; router fidelity gates
    MoE quality). Pure JAX: traceable under jit/eval_shape."""
    def walk(tree, path=()):
        if isinstance(tree, dict):
            if ("w" in tree and hasattr(tree["w"], "ndim")
                    and tree["w"].ndim >= 2
                    and path and path[-1] in QUANT_LINEAR_KEYS
                    and not any(s in path for s in skip)):
                return quantize_linear(tree, bits)
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, path + (i,))
                              for i, v in enumerate(tree))
        return tree
    return walk(params)


# ------------------------------------------------------------------ accounting
def quantized_fraction(params: Any) -> float:
    """Fraction of parameter *bytes* now held in int8."""
    int8 = total = 0
    for leaf in jax.tree.leaves(params):
        b = leaf.size * leaf.dtype.itemsize
        total += b
        if leaf.dtype == jnp.int8:
            int8 += b
    return int8 / max(total, 1)


def model_bytes(params: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))


def simulated_int8_bytes(params: Any, min_size: int = MIN_FAKE_SIZE) -> int:
    """Deployed-size accounting for the fake-quant (CNN) track: leaves the
    simulation quantized count 1 B/param, the FP remainder its real width."""
    total = 0
    for leaf in jax.tree.leaves(params):
        if leaf.ndim >= 2 and leaf.size >= min_size:
            total += leaf.size
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


def simulated_quantized_fraction(params: Any,
                                 min_size: int = MIN_FAKE_SIZE) -> float:
    q = total = 0
    for leaf in jax.tree.leaves(params):
        b = leaf.size * leaf.dtype.itemsize
        total += b
        if leaf.ndim >= 2 and leaf.size >= min_size:
            q += b
    return q / max(total, 1)
