"""HQP compression as a typed artifact layer.

  qtypes   — ``QuantizedLinear`` pytree node the runtime dispatches on
  quantize — shared symmetric-quant math (jitted JAX, one eps convention)
  artifact — ``compress()`` entrypoint -> ``HQPArtifact`` (params + manifest)

See DESIGN.md §Compression-artifact for the format and invariants.
"""
from repro.compress.artifact import (HQPArtifact, HQPManifest,  # noqa: F401
                                     arch_fingerprint, compress, spec_to_tree,
                                     tree_to_spec)
from repro.compress.qtypes import (QuantizedLinear, is_quantized,  # noqa: F401
                                   linear_bytes, linear_kernel, out_features)
from repro.compress.quantize import (EPS, QUANT_LINEAR_KEYS,  # noqa: F401
                                     fake_quant, fake_quant_tree, model_bytes,
                                     quant_error, quantize_linear,
                                     quantize_lm_params, quantized_fraction,
                                     symmetric_quantize)

__all__ = [
    "HQPArtifact", "HQPManifest", "arch_fingerprint", "compress",
    "spec_to_tree",
    "tree_to_spec", "QuantizedLinear", "is_quantized", "linear_bytes",
    "linear_kernel", "out_features", "EPS", "QUANT_LINEAR_KEYS",
    "fake_quant", "fake_quant_tree", "model_bytes", "quant_error",
    "quantize_linear", "quantize_lm_params", "quantized_fraction",
    "symmetric_quantize"]
