"""The HQP artifact: one typed, self-describing compression output.

``compress()`` is the single entrypoint every consumer (serving launcher,
benchmarks, CNN repro, checkpointing) goes through: conditional prune
(Algorithm 1) -> physical compaction -> PTQ, returning an ``HQPArtifact``
whose ``manifest`` is the audit trail — per-family θ, bytes before/after,
quantized byte fraction, and the accept/reject history of the conditional
loop. The paper's "output is a standard model" property becomes "output is a
standard *artifact*": a pytree whose quantized leaves are ``QuantizedLinear``
nodes the runtime dispatches on.

Serialization: ``tree_to_spec``/``spec_to_tree`` encode the pytree structure
(dict/tuple/list/QuantizedLinear) as JSON plus a flat array list, so an
artifact reloads without a template tree (``launch.checkpoint.save_artifact``
adds the atomic-commit envelope).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.compress import quantize as cq
from repro.compress.qtypes import QuantizedLinear


def arch_fingerprint(cfg) -> str:
    """Stable hash of the architecture identity a speculative drafter must
    share with its verifier: same tokenizer space (vocab), same positional
    scheme, same layer pattern. Pruning may shrink member widths (the
    artifact's caches size themselves from param shapes), so widths like
    ``n_kv_heads``/``d_ff`` are deliberately EXCLUDED — a compacted artifact
    keeps its parent's fingerprint. Recorded in the HQP manifest so
    ``serving.speculative`` can refuse a drafter built for a different
    model family before any device work runs."""
    ident = {
        "name": getattr(cfg, "name", None) or getattr(cfg, "arch", "?"),
        "vocab_size": getattr(cfg, "vocab_size", None),
        "n_layers": getattr(cfg, "n_layers", None),
        "d_model": getattr(cfg, "d_model", None),
        "head_dim": (cfg.resolved_head_dim
                     if hasattr(cfg, "resolved_head_dim") else None),
        "pattern": list(getattr(cfg, "pattern", ())),
        "qk_norm": getattr(cfg, "qk_norm", None),
        "rope_theta": getattr(cfg, "rope_theta", None),
        "tie_embeddings": getattr(cfg, "tie_embeddings", None),
    }
    blob = json.dumps(ident, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


# ------------------------------------------------------------------ manifest
@dataclasses.dataclass
class HQPManifest:
    arch: str
    track: str                        # "int8" (LM real) | "fake" (CNN sim)
    bits: int
    bytes_before: int
    bytes_after: int
    quantized_fraction: float
    pruned: bool
    theta: float                      # global structural sparsity
    n_drop: int
    total_units: int
    theta_by_family: Dict[str, float]
    a_baseline: Optional[float]
    a_final: Optional[float]
    history: List[dict]               # accept/reject audit of Algorithm 1
    # drafter-compatibility record (defaults keep pre-speculative artifacts
    # loadable): vocab + arch-identity hash a speculative verifier checks
    # before accepting this artifact as its drafter
    vocab_size: Optional[int] = None
    arch_hash: Optional[str] = None

    def summary(self) -> str:
        lines = [
            f"[hqp] artifact({self.arch}/{self.track}): "
            f"{self.bytes_before / 1e6:.1f}MB -> {self.bytes_after / 1e6:.1f}MB "
            f"({self.bytes_before / max(self.bytes_after, 1):.2f}x), "
            f"quantized {self.quantized_fraction:.0%} of bytes at "
            f"{self.bits}b, θ={self.theta:.1%} "
            f"({self.n_drop}/{self.total_units} units)"]
        if self.a_baseline is not None:
            lines.append(f"[hqp] accuracy {self.a_baseline:.4f} -> "
                         f"{self.a_final:.4f} over {len(self.history)} "
                         f"conditional steps")
        fams = ([f"{k}={v:.0%}" for k, v in sorted(self.theta_by_family.items())
                 if v > 0] or ["(no pruning applied)"])
        for i in range(0, len(fams), 6):
            lines.append("[hqp] θ by family: " + "  ".join(fams[i:i + 6]))
        return "\n".join(lines)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def fromdict(cls, d: dict) -> "HQPManifest":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class HQPArtifact:
    params: Any                       # deployment pytree (QuantizedLinear leaves)
    manifest: HQPManifest


# ------------------------------------------------------------------ compress
def compress(params: Any, cfg, sq_grads: Any = None,
             eval_fn: Optional[Callable[[Any], float]] = None,
             hqp=None, specs=None, a_baseline: Optional[float] = None,
             log: Callable[[str], None] = print) -> HQPArtifact:
    """Full HQP: conditional prune -> compact -> PTQ -> manifest.

    ``sq_grads`` (Fisher diag pytree) + ``eval_fn`` enable the conditional
    prune; without them the prune phase is skipped (PTQ-only artifact).
    ``specs`` defaults to the LM family specs derived from ``cfg``; the CNN
    track passes its own conv-channel specs. ``hqp.track`` selects real INT8
    storage ("int8") or the paper-faithful simulated INT8 ("fake")."""
    # lazy: core.* imports this module's package via core.quantization
    from repro.core import pipeline as pipe
    from repro.core import pruning as pr
    from repro.core import sensitivity as sens

    if (sq_grads is None) != (eval_fn is None):
        raise ValueError(
            "compress(): sq_grads and eval_fn must be given together (both "
            "for conditional pruning, neither for a PTQ-only artifact); got "
            f"sq_grads={'set' if sq_grads is not None else 'None'}, "
            f"eval_fn={'set' if eval_fn is not None else 'None'}")
    hqp = hqp or pipe.HQPConfig(weight_granularity="channel")
    bytes_before = pr.param_bytes(params)
    arch = getattr(cfg, "name", None) or getattr(cfg, "arch", "?")

    deploy = params
    pruned = False
    theta, n_drop, total_units = 0.0, 0, 0
    theta_by_family: Dict[str, float] = {}
    a_final = a_baseline
    history: List[dict] = []
    if sq_grads is not None and eval_fn is not None:
        if specs is None:
            specs = sens.lm_prune_groups(cfg)
        res = pipe.conditional_prune(params, specs, sq_grads, eval_fn, hqp,
                                     a_baseline=a_baseline, log=log)
        deploy = res.params_compact
        pruned = True
        theta, n_drop, total_units = res.theta, res.n_drop, res.ranked.total
        theta_by_family = {k: v["theta"]
                           for k, v in res.sparsity_by_family.items()}
        a_baseline, a_final = res.a_baseline, res.a_final
        history = [dataclasses.asdict(h) for h in res.history]

    if hqp.track == "fake":
        deploy = cq.fake_quant_tree(deploy, hqp.bits, hqp.weight_granularity)
        bytes_after = cq.simulated_int8_bytes(deploy)
        qfrac = cq.simulated_quantized_fraction(deploy)
    else:
        deploy = cq.quantize_lm_params(deploy, hqp.bits)
        bytes_after = cq.model_bytes(deploy)
        qfrac = cq.quantized_fraction(deploy)

    manifest = HQPManifest(
        arch=arch, track=hqp.track, bits=hqp.bits,
        bytes_before=int(bytes_before), bytes_after=int(bytes_after),
        quantized_fraction=float(qfrac), pruned=pruned, theta=float(theta),
        n_drop=int(n_drop), total_units=int(total_units),
        theta_by_family=theta_by_family,
        a_baseline=None if a_baseline is None else float(a_baseline),
        a_final=None if a_final is None else float(a_final),
        history=history,
        vocab_size=getattr(cfg, "vocab_size", None),
        arch_hash=arch_fingerprint(cfg))
    return HQPArtifact(params=deploy, manifest=manifest)


# ------------------------------------------------------------------ (de)spec
def tree_to_spec(tree: Any, arrays: List[np.ndarray]) -> Any:
    """JSON-able structure spec; leaves append to ``arrays`` (bf16 leaves are
    stored as a uint16 view, tagged in the spec)."""
    if isinstance(tree, QuantizedLinear):
        slot = len(arrays)
        arrays.append(np.asarray(tree.w_q))
        arrays.append(np.asarray(tree.scale))
        return {"__kind__": "qlinear", "bits": tree.bits, "slot": slot}
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: tree_to_spec(v, arrays) for k, v in tree.items()}}
    if isinstance(tree, (tuple, list)):
        kind = "tuple" if isinstance(tree, tuple) else "list"
        return {"__kind__": kind,
                "items": [tree_to_spec(v, arrays) for v in tree]}
    if tree is None:
        return {"__kind__": "none"}
    arr = np.asarray(tree)
    slot = len(arrays)
    dtype = str(tree.dtype)
    if dtype == "bfloat16":
        arr = arr.view(np.uint16)
    arrays.append(arr)
    return {"__kind__": "leaf", "slot": slot, "dtype": dtype}


def _leaf_from(arr: np.ndarray, dtype: str):
    import jax.numpy as jnp
    if dtype == "bfloat16":
        import ml_dtypes
        arr = arr.view(ml_dtypes.bfloat16)
    return jnp.asarray(arr)


def spec_to_tree(spec: Any, arrays: List[np.ndarray]) -> Any:
    import jax.numpy as jnp
    kind = spec["__kind__"]
    if kind == "qlinear":
        return QuantizedLinear(w_q=jnp.asarray(arrays[spec["slot"]]),
                               scale=jnp.asarray(arrays[spec["slot"] + 1]),
                               bits=spec["bits"])
    if kind == "dict":
        return {k: spec_to_tree(v, arrays) for k, v in spec["items"].items()}
    if kind in ("tuple", "list"):
        seq = [spec_to_tree(v, arrays) for v in spec["items"]]
        return tuple(seq) if kind == "tuple" else seq
    if kind == "none":
        return None
    return _leaf_from(arrays[spec["slot"]], spec["dtype"])
