"""S-guided dynamic mixed precision (paper §VI-A, implemented beyond-paper).

The filter-sensitivity metric S drives per-structure bit allocation:
lowest-S structures go INT4, the bulk INT8, the most sensitive tail stays
bf16. Storage is int8-backed for both INT4 and INT8 (INT4 uses 15 levels and
is *accounted* at 0.5 B/param for size; a production TPU path would pack two
nibbles per byte — noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List

import jax.numpy as jnp
import numpy as np

from repro.core import sensitivity as sens


@dataclasses.dataclass
class MixedPrecisionPolicy:
    frac_int4: float = 0.25      # lowest-S fraction -> INT4
    frac_bf16: float = 0.05      # highest-S fraction stays bf16
    # remainder -> INT8


def assign_bits(s_values: np.ndarray, policy: MixedPrecisionPolicy) -> np.ndarray:
    """Per-unit bit widths from ascending sensitivity."""
    n = len(s_values)
    order = np.argsort(s_values)
    bits = np.full(n, 8)
    bits[order[: int(policy.frac_int4 * n)]] = 4
    if policy.frac_bf16 > 0:
        bits[order[n - int(policy.frac_bf16 * n):]] = 16
    return bits


def quantize_group_mixed(params: Any, spec: sens.GroupSpec,
                         bits_per_unit: np.ndarray) -> Any:
    """Fake-quantize each unit of a family at its assigned width (eval path).

    Real deployment uses uniform-int8 tensors with per-unit effective level
    counts (scale multiplied up for int4 units) — same arithmetic, one dtype."""
    for path, axis, block, offset in spec.members_all:
        leaf = sens._get(params, path)
        if leaf.ndim < 2:
            continue
        moved = jnp.moveaxis(leaf, axis, 0)
        seg = moved[offset:offset + spec.size * block]
        seg = seg.reshape(spec.size, block, -1).astype(jnp.float32)
        qmax = (2.0 ** (jnp.asarray(bits_per_unit) - 1) - 1)[:, None, None]
        amax = jnp.max(jnp.abs(seg), axis=(1, 2), keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / qmax
        qseg = jnp.clip(jnp.round(seg / scale), -qmax, qmax) * scale
        qseg = qseg.reshape(spec.size * block, -1).reshape(
            moved[offset:offset + spec.size * block].shape)
        moved = moved.at[offset:offset + spec.size * block].set(
            qseg.astype(moved.dtype))
        params = sens._set(params, path, jnp.moveaxis(moved, 0, axis))
    return params


def mixed_precision_bytes(spec_sizes: List[int],
                          bits_assignments: List[np.ndarray],
                          params_per_unit: List[int]) -> float:
    total = 0.0
    for size, bits, ppu in zip(spec_sizes, bits_assignments, params_per_unit):
        total += float(np.sum(bits / 8.0 * ppu))
    return total
