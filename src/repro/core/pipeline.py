"""HQP end-to-end pipeline:  M_o = Q(P(M_train, τ, Δ_ax), b)   (§III).

Algorithm 1 (conditional iterative pruning) + robust PTQ, with the exact
accept/reject semantics of the paper: pruning proceeds in δ-sized steps down
the ascending-S ranked list R and TERMINATES the moment the validation
accuracy drop exceeds Δ_ax; the last *accepted* model is M_sparse, which then
enters PTQ. The returned history is the audit trail used by the repro
benchmarks (accuracy-vs-θ curve, Tables I/II).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional


from repro.core import pruning as pr
from repro.core import sensitivity as sens


@dataclasses.dataclass
class HQPConfig:
    delta_ax: float = 0.015          # max permissible accuracy drop (1.5%)
    step_frac: float = 0.01          # δ: 1% of total structural units / step
    bits: int = 8
    weight_granularity: str = "tensor"   # paper-faithful; "channel" for LM
    act_method: str = "kl"           # absmax | percentile | kl
    max_steps: int = 200
    protect_frac: float = 0.0
    track: str = "int8"              # "int8" real storage | "fake" simulated


@dataclasses.dataclass
class PruneStep:
    step: int
    n_drop: int
    theta: float
    accuracy: float
    drop: float
    accepted: bool
    seconds: float


@dataclasses.dataclass
class HQPResult:
    params_sparse: Any               # masked, maximal compliant (M_sparse)
    params_compact: Any              # physically compacted
    ranked: pr.RankedUnits
    n_drop: int
    theta: float
    a_baseline: float
    a_final: float
    history: List[PruneStep]

    @property
    def sparsity_by_family(self):
        return pr.sparsity_report(self.ranked, self.n_drop)


def conditional_prune(params: Any,
                      specs: List[sens.GroupSpec],
                      sq_grads: Any,
                      eval_fn: Callable[[Any], float],
                      hqp: HQPConfig,
                      a_baseline: Optional[float] = None,
                      log: Callable[[str], None] = print) -> HQPResult:
    """Algorithm 1. eval_fn: masked params -> accuracy in [0, 1]."""
    ranked = pr.rank_units(specs, sq_grads, hqp.protect_frac)
    if a_baseline is None:
        a_baseline = eval_fn(params)
    delta = max(1, int(hqp.step_frac * ranked.total))
    log(f"[hqp] baseline acc={a_baseline:.4f}  units={ranked.total}  "
        f"δ={delta}  Δ_ax={hqp.delta_ax}")

    history: List[PruneStep] = []
    best_n, best_acc = 0, a_baseline
    n_drop = 0
    for t in range(1, hqp.max_steps + 1):
        n_drop = min(n_drop + delta, ranked.total)
        t0 = time.time()
        candidate = pr.apply_prune_masks(params, ranked, n_drop)
        acc = float(eval_fn(candidate))
        dt = time.time() - t0
        drop = a_baseline - acc
        accepted = drop <= hqp.delta_ax
        theta = n_drop / ranked.total
        history.append(PruneStep(t, n_drop, theta, acc, drop, accepted, dt))
        log(f"[hqp] step {t:3d} θ={theta:5.1%} acc={acc:.4f} "
            f"drop={drop:+.4f} {'ACCEPT' if accepted else 'REJECT -> stop'}")
        if not accepted:
            break
        best_n, best_acc = n_drop, acc
        if n_drop >= ranked.total:
            break

    params_sparse = pr.apply_prune_masks(params, ranked, best_n)
    # compact from the MASKED params: stacked-family padding units must carry
    # zeros so the compacted artifact == the validated masked model
    params_compact = pr.compact_params(params_sparse, ranked, best_n)
    return HQPResult(params_sparse, params_compact, ranked, best_n,
                     best_n / ranked.total, a_baseline, best_acc, history)


def hqp_compress_lm(params: Any, cfg, sq_grads: Any,
                    eval_fn: Callable[[Any], float],
                    hqp: Optional[HQPConfig] = None,
                    log: Callable[[str], None] = print):
    """Full HQP for the unified LM — thin wrapper over the typed artifact
    entrypoint (``repro.compress.compress``), kept for its historical
    signature. Returns the ``HQPArtifact``; prefer calling compress()."""
    from repro.compress import compress
    hqp = hqp or HQPConfig(weight_granularity="channel")
    return compress(params, cfg, sq_grads=sq_grads, eval_fn=eval_fn,
                    hqp=hqp, log=log)
