"""Activation/weight range calibration: absmax, percentile, KL-divergence.

The KL method is the TensorRT entropy calibration the paper relies on
(§IV-B "TensorRT performs the KL-Divergence calibration on D_calib"):
histogram |x| into fine bins, then for each candidate clip threshold T build
P (clipped reference distribution, tail mass folded into the last bin) and Q
(P re-quantized to 2^{b-1}-1 levels and re-expanded), and pick the T
minimizing KL(P||Q).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

N_BINS = 2048


# ------------------------------------------------------------------ methods
def absmax_scale(amax: float, bits: int = 8) -> float:
    return max(amax, 1e-8) / (2 ** (bits - 1) - 1)


def percentile_threshold(hist: np.ndarray, edges: np.ndarray,
                         pct: float = 99.99) -> float:
    cdf = np.cumsum(hist) / max(hist.sum(), 1)
    idx = int(np.searchsorted(cdf, pct / 100.0))
    return float(edges[min(idx + 1, len(edges) - 1)])


def _kl_div(p: np.ndarray, q: np.ndarray) -> float:
    mask = p > 0
    qm = np.where(q > 0, q, 1e-12)
    return float(np.sum(p[mask] * np.log(p[mask] / qm[mask])))


def kl_threshold(hist: np.ndarray, edges: np.ndarray, bits: int = 8) -> float:
    """TensorRT-style entropy calibration over an |x| histogram.

    Two guards against the ReLU-spike failure mode (a dominant zero bin makes
    KL favor near-total clipping): the zero bin is excluded from the
    divergence (TRT does the same), and the returned threshold is floored at
    the 99th-percentile threshold — KL may only *refine* within the top
    percentile, never clip below it."""
    n_levels = 2 ** (bits - 1) - 1                       # 127 for int8
    hist = hist.astype(np.float64).copy()
    hist[0] = 0.0                                        # exclude zero spike
    floor_t = percentile_threshold(hist, edges, 99.0)
    best_kl, best_i = np.inf, N_BINS
    start = max(n_levels, N_BINS // 16)
    for i in range(start, N_BINS + 1, 8):
        p = hist[:i].copy()
        p[i - 1] += hist[i:].sum()                       # fold clipped tail
        if p.sum() == 0:
            continue
        # quantize the first i bins down to n_levels and expand back
        chunks = np.array_split(hist[:i], n_levels)
        q = np.zeros(i)
        pos = 0
        for ch in chunks:
            nz = (ch > 0).sum()
            total = ch.sum()
            if nz > 0:
                q[pos:pos + len(ch)][ch > 0] = total / nz
            pos += len(ch)
        p /= p.sum()
        qs = q.sum()
        if qs == 0:
            continue
        q /= qs
        kl = _kl_div(p, q)
        if kl < best_kl:
            best_kl, best_i = kl, i
    return max(float(edges[best_i]), floor_t)


# ------------------------------------------------------------------ collector
@dataclasses.dataclass
class TensorStats:
    amax: float = 0.0
    hist: Optional[np.ndarray] = None
    edges: Optional[np.ndarray] = None

    def update_amax(self, x: np.ndarray):
        self.amax = max(self.amax, float(np.max(np.abs(x))))

    def update_hist(self, x: np.ndarray):
        if self.hist is None:
            self.edges = np.linspace(0.0, max(self.amax, 1e-8), N_BINS + 1)
            self.hist = np.zeros(N_BINS)
        h, _ = np.histogram(np.abs(x), bins=self.edges)
        self.hist += h

    def scale(self, method: str = "kl", bits: int = 8) -> float:
        if method == "absmax" or self.hist is None:
            return absmax_scale(self.amax, bits)
        if method == "percentile":
            t = percentile_threshold(self.hist, self.edges)
        elif method == "kl":
            t = kl_threshold(self.hist, self.edges, bits)
        else:
            raise ValueError(method)
        return absmax_scale(t, bits)


class ActQ:
    """Activation-quantization tap threaded through model apply fns.

    mode="amax"  : pass 1 — record per-site absmax (un-jitted).
    mode="hist"  : pass 2 — accumulate |x| histograms (un-jitted).
    mode="apply" : fake-quantize with calibrated static scales (jit-safe).
    mode=None    : no-op.
    """

    def __init__(self, mode: Optional[str] = None, bits: int = 8,
                 method: str = "kl"):
        self.mode = mode
        self.bits = bits
        self.method = method
        self.stats: Dict[str, TensorStats] = {}
        self.scales: Dict[str, float] = {}

    def tap(self, name: str, x: jax.Array) -> jax.Array:
        if self.mode is None:
            return x
        if self.mode == "amax":
            self.stats.setdefault(name, TensorStats()).update_amax(np.asarray(x))
            return x
        if self.mode == "hist":
            self.stats[name].update_hist(np.asarray(x))
            return x
        if self.mode == "apply":
            s = self.scales[name]
            qmax = 2 ** (self.bits - 1) - 1
            return (jnp.clip(jnp.round(x / s), -qmax, qmax) * s).astype(x.dtype)
        raise ValueError(self.mode)

    def finalize(self):
        self.scales = {k: st.scale(self.method, self.bits)
                       for k, st in self.stats.items()}
        self.mode = "apply"
        return self
