"""HQP core: the paper's contribution as composable JAX transforms.

  sensitivity   — diagonal-FIM structural saliency S (§II-B)
  pruning       — global ascending-S ranking, mask / compact surgery
  calibration   — absmax / percentile / KL (TensorRT-style) range search
  quantization  — paper-faithful per-tensor PTQ sim + production INT8 storage
  pipeline      — Algorithm 1 conditional loop + Q∘P composition
  mixed_precision — §VI-A S-guided INT4/INT8/BF16 allocation (beyond-paper)

The deployment-facing entrypoint is ``repro.compress.compress`` — it wraps
``pipeline.conditional_prune`` + compaction + PTQ into a typed artifact.
"""
from repro.core import (calibration, mixed_precision, pipeline, pruning,  # noqa: F401
                        quantization, sensitivity)
from repro.core.pipeline import (HQPConfig, HQPResult, conditional_prune,  # noqa: F401
                                 hqp_compress_lm)

__all__ = [
    "calibration", "mixed_precision", "pipeline", "pruning",
    "quantization", "sensitivity", "HQPConfig", "HQPResult",
    "conditional_prune", "hqp_compress_lm"]
