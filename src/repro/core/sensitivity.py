"""Filter/structure sensitivity via the diagonal Fisher approximation (§II-B).

    S_g = (1/|D_calib|) Σ_i || ∂L(W, x_i, y_i)/∂W_g ||²

One backward pass over the calibration set accumulates squared gradients
(the diagonal FIM estimate); structural group sensitivities are produced by
summing the diagonal over each group's parameter slices. The same machinery
drives conv filters (CNN repro track) and attention-KV-head / FFN-column /
expert / Mamba-channel / mLSTM-head units (LM fleet).

Member encoding
---------------
A *member* is (path, axis, block, offset): the leaf at ``path`` holds
``size`` units along ``axis``, unit ``u`` occupying rows/cols
``[offset + u*block, offset + (u+1)*block)``. Stacked-layer leaves (the LM's
scan-over-layers layout, leading dim = layer group) are addressed with a
``("__stack__", g)`` path prefix selecting layer ``g``; axes are then in
unstacked coordinates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Member = Tuple[Tuple, int, int, int]     # (path, axis, block, offset)


# ------------------------------------------------------------------ FIM diag
def fisher_diag(grad_fn: Callable[[Any, Any], Any], params: Any,
                calib_batches: Iterable[Any]) -> Tuple[Any, int]:
    """E[g²] over the calibration set. grad_fn(params, batch) -> grad pytree."""
    acc = None
    n = 0
    for batch in calib_batches:
        g = grad_fn(params, batch)
        sq = jax.tree.map(lambda t: jnp.square(t.astype(jnp.float32)), g)
        acc = sq if acc is None else jax.tree.map(jnp.add, acc, sq)
        n += 1
    if n == 0:
        raise ValueError("empty calibration set")
    return jax.tree.map(lambda t: t / n, acc), n


# ------------------------------------------------------------------ groups
@dataclasses.dataclass
class GroupSpec:
    name: str
    members_grad: List[Member]   # leaves contributing to S
    members_all: List[Member]    # every leaf to zero/remove on pruning
    size: int                    # number of units (channels/heads/experts)
    kind: str = "channel"


def m(path, axis, block=1, offset=0) -> Member:
    return (tuple(path), axis, block, offset)


def _get(tree, path):
    if path and path[0] == "__stack__":
        return _get(tree, path[2:])[path[1]]
    for p in path:
        tree = tree[p]
    return tree


def _set(tree, path, value):
    if path and path[0] == "__stack__":
        g = path[1]
        full = _get(tree, path[2:])
        return _set(tree, path[2:], full.at[g].set(value))
    key = path[0]
    sub = value if len(path) == 1 else _set(tree[key], path[1:], value)
    if isinstance(tree, (tuple, list)):
        out = list(tree)
        out[key] = sub
        return type(tree)(out)
    return {**tree, key: sub}


def group_sensitivity(sq_grads: Any, spec: GroupSpec) -> jax.Array:
    """S per unit: sum of E[g²] over each unit's slices across members."""
    s = jnp.zeros((spec.size,), jnp.float32)
    for path, axis, block, offset in spec.members_grad:
        leaf = jnp.moveaxis(_get(sq_grads, path), axis, 0)
        sl = leaf[offset:offset + spec.size * block]
        sl = sl.reshape(spec.size, block, -1)
        s = s + jnp.sum(sl, axis=(1, 2))
    return s


def _axis_mask(keep: jax.Array, length: int, block: int, offset: int):
    vec = jnp.ones((length,), jnp.float32)
    unit = jnp.repeat(keep.astype(jnp.float32), block)
    return jax.lax.dynamic_update_slice(vec, unit, (offset,))


def mask_group(params: Any, spec: GroupSpec, drop: jax.Array) -> Any:
    """Zero the units selected by boolean ``drop`` (size,). Shape-preserving."""
    keep = ~drop
    for path, axis, block, offset in spec.members_all:
        leaf = _get(params, path)
        vec = _axis_mask(keep, leaf.shape[axis], block, offset)
        shape = [1] * leaf.ndim
        shape[axis] = leaf.shape[axis]
        params = _set(params, path, leaf * vec.reshape(shape).astype(leaf.dtype))
    return params


def compact_group(params: Any, spec: GroupSpec, keep_units: np.ndarray) -> Any:
    """Physically remove pruned units (deployment artifact).

    Members sharing a (leaf, axis) — e.g. the two halves of a gated
    up-projection — are compacted in ONE gather, since removing the first
    member's slices would shift the second member's offsets."""
    by_leaf = {}
    for path, axis, block, offset in spec.members_all:
        by_leaf.setdefault((tuple(path), axis), []).append((block, offset))
    for (path, axis), members in by_leaf.items():
        leaf = _get(params, path)
        length = leaf.shape[axis]
        keep_mask = np.ones(length, bool)
        for block, offset in members:
            drop_units = np.setdiff1d(np.arange(spec.size), keep_units)
            idx = (offset + drop_units[:, None] * block
                   + np.arange(block)[None, :]).reshape(-1)
            keep_mask[idx] = False
        full = np.nonzero(keep_mask)[0]
        params = _set(params, path, jnp.take(leaf, jnp.asarray(full), axis=axis))
    return params


# ------------------------------------------------------------------ CNN specs
def cnn_prune_groups(cfg, variables: dict) -> List[GroupSpec]:
    """Prunable channel families for the paper's two architectures.

    ResNet-18: the conv1 (intra-block) channels of every basic block — the
    residual-identity path is never pruned (§V-D alignment discussion).
    MobileNetV3-S: the expansion channels of every inverted bottleneck (the
    family the paper found highest-sparsity, §V-C).
    """
    p = variables["params"]
    groups: List[GroupSpec] = []
    import re as _re
    if cfg.arch == "resnet18":
        for name in sorted(k for k in p if _re.match(r"^s\d+b\d+$", k)):
            c = p[name]["conv1"].shape[3]
            mg = [m(("params", name, "conv1"), 3),
                  m(("params", name, "conv2"), 2),
                  m(("params", name, "bn1", "scale"), 0)]
            ma = mg + [m(("params", name, "bn1", "bias"), 0),
                       m(("stats", name, "bn1", "mean"), 0),
                       m(("stats", name, "bn1", "var"), 0)]
            groups.append(GroupSpec(f"{name}/conv1", mg, ma, c))
    else:  # mobilenetv3s
        for name in sorted((k for k in p if _re.match(r"^b\d+$", k)
                            and isinstance(p[k], dict) and "expand" in p[k]),
                           key=lambda s: int(s[1:])):
            blk = p[name]
            c = blk["expand"].shape[3]
            mg = [m(("params", name, "expand"), 3),
                  m(("params", name, "dw"), 3),
                  m(("params", name, "project"), 2),
                  m(("params", name, "bn_e", "scale"), 0),
                  m(("params", name, "bn_d", "scale"), 0)]
            ma = list(mg) + [m(("params", name, "bn_e", "bias"), 0),
                             m(("params", name, "bn_d", "bias"), 0),
                             m(("stats", name, "bn_e", "mean"), 0),
                             m(("stats", name, "bn_e", "var"), 0),
                             m(("stats", name, "bn_d", "mean"), 0),
                             m(("stats", name, "bn_d", "var"), 0)]
            if "se_down" in blk:
                ma += [m(("params", name, "se_down", "w"), 2),
                       m(("params", name, "se_up", "w"), 3),
                       m(("params", name, "se_up", "b"), 0)]
            groups.append(GroupSpec(f"{name}/expand", mg, ma, c))
    return groups


# ------------------------------------------------------------------ LM specs
def lm_prune_groups(cfg) -> List[GroupSpec]:
    """Structural families for the unified LM (stacked-layer layout).

    One family per (period-position, layer) pair — masks are per-layer, so the
    conditional loop can produce the paper's non-uniform layer-wise sparsity.
    sLSTM blocks are left unpruned (nonlinear recurrent alignment; DESIGN.md
    §Arch-applicability).
    """
    from repro.models.lm import layer_specs, pattern_period
    period = pattern_period(cfg)
    n_groups = cfg.n_layers // period
    spec = layer_specs(cfg)[:period]
    hd = cfg.resolved_head_dim
    g_ratio = cfg.n_heads // cfg.n_kv_heads
    out: List[GroupSpec] = []
    for j, (kind, is_moe) in enumerate(spec):
        for g in range(n_groups):
            st = ("__stack__", g, "blocks", j)
            tag = f"L{g * period + j}"
            if kind == "attn":
                mm = [m(st + ("attn", "wq", "w"), 1, g_ratio * hd),
                      m(st + ("attn", "wk", "w"), 1, hd),
                      m(st + ("attn", "wv", "w"), 1, hd),
                      m(st + ("attn", "wo", "w"), 0, g_ratio * hd)]
                out.append(GroupSpec(f"{tag}/kv_heads", mm, list(mm),
                                     cfg.n_kv_heads, kind="kv_head"))
            if kind in ("attn", "mamba") and cfg.d_ff > 0 and not is_moe:
                mm = [m(st + ("mlp", "gate", "w"), 1),
                      m(st + ("mlp", "up", "w"), 1),
                      m(st + ("mlp", "down", "w"), 0)]
                out.append(GroupSpec(f"{tag}/ffn", mm, list(mm),
                                     cfg.d_ff, kind="ffn_col"))
            if is_moe:
                mm = [m(st + ("moe", "gate", "w"), 0),
                      m(st + ("moe", "up", "w"), 0),
                      m(st + ("moe", "down", "w"), 0)]
                out.append(GroupSpec(
                    f"{tag}/experts", mm,
                    mm + [m(st + ("moe", "router", "w"), 1)],
                    cfg.moe.n_experts, kind="expert"))
            if kind == "mamba":
                d_in = cfg.ssm.expand * cfg.d_model
                mm = [m(st + ("mamba", "x_proj", "w"), 0),
                      m(st + ("mamba", "out_proj", "w"), 0),
                      m(st + ("mamba", "dt_proj", "w"), 1)]
                ma = mm + [m(st + ("mamba", "dt_proj", "b"), 0),
                           m(st + ("mamba", "conv_w"), 1),
                           m(st + ("mamba", "a_log"), 0),
                           m(st + ("mamba", "d_skip"), 0),
                           m(st + ("mamba", "in_proj", "w"), 1, 1, 0),
                           m(st + ("mamba", "in_proj", "w"), 1, 1, d_in)]
                out.append(GroupSpec(f"{tag}/mamba_cols", mm, ma,
                                     d_in, kind="mamba_col"))
            if kind == "mlstm":
                d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
                head_d = d_in // cfg.n_heads
                mm = [m(st + ("mlstm", "wq"), 0),
                      m(st + ("mlstm", "wk"), 0),
                      m(st + ("mlstm", "wv"), 0)]
                ma = mm + [m(st + ("mlstm", "w_i", "w"), 1),
                           m(st + ("mlstm", "w_i", "b"), 0),
                           m(st + ("mlstm", "w_f", "w"), 1),
                           m(st + ("mlstm", "w_f", "b"), 0),
                           m(st + ("mlstm", "w_i", "w"), 0, head_d),
                           m(st + ("mlstm", "w_f", "w"), 0, head_d),
                           m(st + ("mlstm", "in_proj", "w"), 1, head_d, 0),
                           m(st + ("mlstm", "in_proj", "w"), 1, head_d, d_in),
                           m(st + ("mlstm", "norm", "g"), 0, head_d),
                           m(st + ("mlstm", "out_proj", "w"), 0, head_d)]
                out.append(GroupSpec(f"{tag}/mlstm_heads", mm, ma,
                                     cfg.n_heads, kind="mlstm_head"))
    return out
