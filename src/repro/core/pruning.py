"""Global sensitivity ranking + mask/compact application (Algorithm 1 support).

The ranked list R (ascending S, paper line 8) is materialized once from the
single Fisher pass; the conditional loop then asks for "the masked model at
cumulative drop count n" — recomputed from R each iteration (masks are cheap
parameter transforms; the model code never changes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sensitivity as sens


@dataclasses.dataclass
class RankedUnits:
    """Global ascending-S ranking over all structural units."""
    specs: List[sens.GroupSpec]
    spec_idx: np.ndarray        # (total,) which family
    unit_idx: np.ndarray        # (total,) unit within family
    s_values: np.ndarray        # (total,) ascending

    @property
    def total(self) -> int:
        return len(self.s_values)

    def drops_per_spec(self, n_drop: int) -> List[np.ndarray]:
        """Unit indices dropped in each family for cumulative count n_drop."""
        sel_spec = self.spec_idx[:n_drop]
        sel_unit = self.unit_idx[:n_drop]
        return [sel_unit[sel_spec == i] for i in range(len(self.specs))]


def rank_units(specs: Sequence[sens.GroupSpec], sq_grads: Any,
               protect_frac: float = 0.0) -> RankedUnits:
    """Build R. ``protect_frac``: never rank the top-S fraction of each family
    (guards against emptying a whole layer; 0 = paper-faithful pure ranking)."""
    all_s, all_spec, all_unit = [], [], []
    for i, sp in enumerate(specs):
        s = np.asarray(sens.group_sensitivity(sq_grads, sp))
        n_rankable = sp.size - int(np.ceil(protect_frac * sp.size))
        order = np.argsort(s)[:n_rankable]
        all_s.append(s[order])
        all_spec.append(np.full(len(order), i))
        all_unit.append(order)
    s_cat = np.concatenate(all_s)
    spec_cat = np.concatenate(all_spec)
    unit_cat = np.concatenate(all_unit)
    g_order = np.argsort(s_cat, kind="stable")
    return RankedUnits(list(specs), spec_cat[g_order], unit_cat[g_order],
                       s_cat[g_order])


def apply_prune_masks(params: Any, ranked: RankedUnits, n_drop: int) -> Any:
    """Masked (shape-preserving) model with the first n_drop units of R zeroed."""
    for spec, drops in zip(ranked.specs, ranked.drops_per_spec(n_drop)):
        if len(drops) == 0:
            continue
        dvec = np.zeros((spec.size,), bool)
        dvec[drops] = True
        params = sens.mask_group(params, spec, jnp.asarray(dvec))
        if spec.kind == "expert":
            params = _disable_router_cols(params, spec, dvec)
    return params


def _disable_router_cols(params, spec, dvec):
    """Masked experts must also be unroutable: router bias -> -inf."""
    router_member = [mm for mm in spec.members_all
                     if "router" in mm[0]]
    if not router_member:
        return params
    path = router_member[0][0][:-1] + ("b",)
    b = sens._get(params, path)
    b = jnp.where(jnp.asarray(dvec), -1e9, b)
    return sens._set(params, path, b)


def compact_params(params: Any, ranked: RankedUnits, n_drop: int) -> Any:
    """Physically remove the first n_drop units of R (deployment artifact).

    CNN (unstacked) families compact exactly per-family. LM stacked families
    (scan-over-layers leaves, one family per layer) must stay SHAPE-UNIFORM
    across the stack: the family group keeps ``size - min_g(dropped_g)``
    units per layer; more-pruned layers pad with their own *masked* (zeroed)
    units, so the compacted model computes exactly what the masked model
    computed (tests/test_hqp.py::test_lm_mask_equals_compact). Call with the
    MASKED params for stacked trees."""
    drops_all = ranked.drops_per_spec(n_drop)

    # ---- unstacked families: exact per-family compaction ----
    stacked = {}
    for spec, drops in zip(ranked.specs, drops_all):
        if spec.members_all and spec.members_all[0][0][0] == "__stack__":
            key = (spec.kind, tuple(
                (m[0][2:], m[1], m[2], m[3]) for m in spec.members_all),
                spec.size)
            stacked.setdefault(key, []).append((spec, drops))
            continue
        keep = np.setdiff1d(np.arange(spec.size), drops)
        if len(keep) == spec.size:
            continue
        params = sens.compact_group(params, spec, keep)

    # ---- stacked families: uniform keep count per layer group ----
    for (kind, members, size), entries in stacked.items():
        n_keep = size - min(len(d) for _, d in entries)
        if n_keep == size:
            continue
        keep_per_g = {}
        for spec, drops in entries:
            g = spec.members_all[0][0][1]
            kept = np.setdiff1d(np.arange(size), drops)
            pad = np.asarray(drops, int)[: n_keep - len(kept)]
            keep_per_g[g] = np.sort(np.concatenate([kept, pad]))
        # one gather per (leaf, axis), merging same-leaf members
        by_leaf = {}
        for path, axis, block, offset in members:
            by_leaf.setdefault((path, axis), []).append((block, offset))
        for (path, axis), mems in by_leaf.items():
            full = sens._get(params, path)          # stacked (G, ...)
            gathered = []
            for g in range(full.shape[0]):
                ku = keep_per_g.get(g, np.arange(size))
                length = full.shape[axis + 1]
                mask = np.ones(length, bool)
                for block, offset in mems:
                    du = np.setdiff1d(np.arange(size), ku)
                    idx = (offset + du[:, None] * block
                           + np.arange(block)[None, :]).reshape(-1)
                    mask[idx] = False
                gathered.append(jnp.take(full[g],
                                         jnp.asarray(np.nonzero(mask)[0]),
                                         axis=axis))
            params = sens._set(params, path, jnp.stack(gathered))
    return params


def sparsity_report(ranked: RankedUnits, n_drop: int) -> dict:
    """Per-family sparsity θ (the paper's §V-C non-uniform layer analysis)."""
    rep = {}
    for spec, drops in zip(ranked.specs, ranked.drops_per_spec(n_drop)):
        rep[spec.name] = {"kind": spec.kind, "size": spec.size,
                          "dropped": int(len(drops)),
                          "theta": len(drops) / spec.size}
    return rep


def param_count(params: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def param_bytes(params: Any) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(params))
