"""Post-training quantization (HQP Phase 2).

Two consumers:
  * CNN repro track — *simulated* INT8 (fake-quant weights + calibrated
    activation taps) with the paper's per-tensor step size s = R/(2^b - 1),
    reproducing the pruning-quantization-conflict phenomenon exactly as
    analyzed in §II-C.
  * LM fleet — *real* INT8 storage: linear params become {"w_q" int8,
    "scale" f32 per-out-channel}, executed by the W8A8 Pallas kernel (TPU)
    or the int8 dot_general path (XLA). Per-channel granularity is the
    beyond-paper production choice; per-tensor is available for ablation.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ weights
def quant_error(w: jax.Array, bits: int, granularity: str) -> float:
    q, scale, _ = _quantize_array(np.asarray(w, np.float32), bits, granularity)
    deq = q * scale
    return float(np.sqrt(np.mean((np.asarray(w, np.float32) - deq) ** 2)))


def _quantize_array(w: np.ndarray, bits: int, granularity: str,
                    axis: int = -1) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (q int, scale broadcastable, qmax). Symmetric."""
    qmax = 2 ** (bits - 1) - 1
    if granularity == "tensor":
        amax = np.max(np.abs(w))
        scale = np.maximum(amax, 1e-8) / qmax
        scale = np.asarray(scale)[None]
    else:  # per output channel (last axis by convention)
        red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
        amax = np.max(np.abs(w), axis=red, keepdims=True)
        scale = np.maximum(amax, 1e-8) / qmax
    q = np.clip(np.round(w / scale), -qmax, qmax)
    return q, scale, qmax


def fake_quant(w: jax.Array, bits: int = 8,
               granularity: str = "tensor") -> jax.Array:
    """Dequantized-after-quantize weights (accuracy simulation path)."""
    q, scale, _ = _quantize_array(np.asarray(w, np.float32), bits, granularity)
    return jnp.asarray((q * scale).astype(np.float32), dtype=w.dtype)


def fake_quant_tree(params: Any, bits: int = 8, granularity: str = "tensor",
                    min_size: int = 64) -> Any:
    """Fake-quantize every weight leaf with >= min_size elements (CNN track).

    BN params/stats and small vectors stay FP32 (TensorRT folds/keeps them)."""
    def fq(leaf):
        if leaf.ndim >= 2 and leaf.size >= min_size:
            return fake_quant(leaf, bits, granularity)
        return leaf
    return jax.tree.map(fq, params)


# ------------------------------------------------------------------ LM real INT8
QUANT_LINEAR_KEYS = ("wq", "wk", "wv", "wo", "gate", "up", "down",
                     "in_proj", "out_proj", "frontend")


def quantize_linear(p: Dict[str, jax.Array], bits: int = 8) -> Dict[str, jax.Array]:
    """{"w": (.., in, out)} -> {"w_q" int8, "scale" (.., out) f32}.

    Handles stacked (L, in, out) and expert (L, E, in, out) layouts: the scale
    is per-out-channel within each leading index."""
    w = np.asarray(p["w"], np.float32)
    qmax = 2 ** (bits - 1) - 1
    amax = np.max(np.abs(w), axis=-2, keepdims=True)     # reduce the in-axis
    scale = np.maximum(amax, 1e-12) / qmax
    q = np.clip(np.round(w / scale), -qmax, qmax).astype(np.int8)
    return {"w_q": jnp.asarray(q), "scale": jnp.asarray(
        np.squeeze(scale, -2).astype(np.float32))}


def quantize_lm_params(params: Any, bits: int = 8,
                       skip: Tuple[str, ...] = ("router", "dt_proj", "x_proj"),
                       ) -> Any:
    """Walk the LM param tree; replace quantizable linears with INT8 form.

    Embeddings, norms, routers and the small SSM projections stay
    high-precision (standard practice; router fidelity gates MoE quality)."""
    def walk(tree, path=()):
        if isinstance(tree, dict):
            if ("w" in tree and isinstance(tree["w"], jax.Array)
                    and tree["w"].ndim >= 2
                    and path and path[-1] in QUANT_LINEAR_KEYS
                    and not any(s in path for s in skip)):
                return quantize_linear(tree, bits)
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, path + (i,))
                              for i, v in enumerate(tree))
        return tree
    return walk(params)


def quantized_fraction(params: Any) -> float:
    """Fraction of parameter *bytes* now held in int8."""
    int8 = total = 0
    for leaf in jax.tree.leaves(params):
        b = leaf.size * leaf.dtype.itemsize
        total += b
        if leaf.dtype == jnp.int8:
            int8 += b
    return int8 / max(total, 1)


def model_bytes(params: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
