"""Post-training quantization (HQP Phase 2) — compat surface.

The implementation lives in ``repro.compress.quantize`` (jitted JAX, one
shared symmetric-quant helper, one epsilon convention); this module re-exports
it so the paper-track code keeps its historical import path.

Two consumers:
  * CNN repro track — *simulated* INT8 (fake-quant weights + calibrated
    activation taps) with the paper's per-tensor step size s = R/(2^b - 1),
    reproducing the pruning-quantization-conflict phenomenon exactly as
    analyzed in §II-C.
  * LM fleet — *real* INT8 storage: linear params become typed
    ``QuantizedLinear`` nodes (int8 weights + per-out-channel f32 scales),
    executed by the registered backend (Pallas W8A8 on TPU, XLA int8
    dot_general elsewhere). Per-channel granularity is the beyond-paper
    production choice; per-tensor is available for ablation.
"""
from __future__ import annotations

from repro.compress.quantize import (EPS, QUANT_LINEAR_KEYS,
                                     fake_quant, fake_quant_tree, model_bytes,
                                     quant_error, quantize_linear,
                                     quantize_lm_params, quantized_fraction,
                                     symmetric_quantize)

__all__ = ["EPS", "QUANT_LINEAR_KEYS", "fake_quant", "fake_quant_tree",
           "model_bytes", "quant_error", "quantize_linear",
           "quantize_lm_params", "quantized_fraction", "symmetric_quantize"]
