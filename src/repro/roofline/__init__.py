from repro.roofline.hardware import TPU_V5E  # noqa: F401
from repro.roofline.hlo_analysis import collective_stats, roofline_terms  # noqa: F401

__all__ = ["TPU_V5E", "collective_stats", "roofline_terms"]
