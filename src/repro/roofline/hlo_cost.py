"""Loop-aware HLO cost analyzer.

XLA's built-in ``compiled.cost_analysis()`` visits a while-loop body ONCE —
for scan-over-layers models that undercounts FLOPs/bytes by the trip count
(verified in tests/test_roofline.py). This analyzer parses the optimized HLO
text and computes, with while bodies multiplied by their
``backend_config known_trip_count`` (scan bounds):

  flops            — dot ops: 2 · prod(result dims) · prod(contracting dims),
                     including dots inside fusions (recursed); conditionals
                     take the max branch.
  bytes            — Σ over top-level ops of (operand + result) bytes. In
                     scheduled HLO every top-level op is a fusion boundary,
                     so this approximates HBM traffic like XLA's own
                     "bytes accessed", but loop-corrected.
  collective bytes — result sizes of all-gather / all-reduce / reduce-scatter
                     / all-to-all / collective-permute, loop-corrected, split
                     by kind.
  int8_dot_flops   — dot FLOPs whose operands are int8 (HQP W8A8 path), so
                     the roofline can rate them at the int8 MXU peak.

Elementwise/reduce FLOPs are ignored (≪ dot FLOPs in every cell here);
custom-calls are opaque (the dry-run lowers the pure-XLA model, not Pallas).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from ..analysis.hlo_core import DTYPE_BYTES

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_AFTER_RE = re.compile(r"^\s*([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _split_rhs(rhs: str):
    """'TYPE opcode(operands), attrs' -> (result_text, opcode, rest) or None.

    Handles tuple result types containing /*index=N*/ comments."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    result = rhs[:i + 1]
                    m = _OPCODE_AFTER_RE.match(rhs[i + 1:])
                    if not m:
                        return None
                    return result, m.group(1), rhs[i + 1 + m.end():]
        return None
    m = re.match(r"^(\S+)\s+([a-z][a-z0-9\-]*)\(", rhs)
    if not m:
        return None
    return m.group(1), m.group(2), rhs[m.end():]


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems_first(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_text: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion: bool = False
    param_names: Optional[Dict[int, str]] = None

    def __post_init__(self):
        if self.param_names is None:
            self.param_names = {}


@dataclasses.dataclass
class CostResult:
    flops: float = 0.0
    bytes: float = 0.0
    int8_dot_flops: float = 0.0
    coll_bytes: Optional[Dict[str, float]] = None
    coll_counts: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll_bytes is None:
            self.coll_bytes = {c: 0.0 for c in COLLECTIVES}
        if self.coll_counts is None:
            self.coll_counts = {c: 0.0 for c in COLLECTIVES}

    def add(self, other: "CostResult", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.int8_dot_flops += other.int8_dot_flops * mult
        for c in COLLECTIVES:
            self.coll_bytes[c] += other.coll_bytes[c] * mult
            self.coll_counts[c] += other.coll_counts[c] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


_FREE_OPS = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota")


class HloCost:
    def __init__(self, text: str):
        self.comps: Dict[str, Computation] = {}
        self.shape: Dict[str, str] = {}        # op name -> result type text
        self.producer: Dict[str, "Op"] = {}    # op name -> defining op
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, CostResult] = {}

    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = re.sub(r"/\*.*?\*/", "", raw.strip())
            if line.endswith("{") and "->" in line and "=" not in line.split(
                    "->")[0]:
                m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
                if m:
                    cur = Computation(m.group(2), [],
                                      is_fusion="fused" in m.group(2)
                                      or "wrapped" in m.group(2))
                    self.comps[cur.name] = cur
                    if m.group(1):
                        self.entry = cur.name
                continue
            if cur is None or line == "}" or not line:
                continue
            mo = _OP_RE.match(line)
            if not mo:
                continue
            name, rhs = mo.group(1), mo.group(2)
            split = _split_rhs(rhs)
            if split is None:
                continue
            result_text, opcode, rest = split
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_text, attrs = rest[:end], rest[end + 1:]
            self.shape[name] = result_text
            if opcode == "parameter":
                digits = operand_text.strip()
                if digits.isdigit():
                    cur.param_names[int(digits)] = name
            op = Op(name, opcode, result_text,
                    _OPERAND_RE.findall(operand_text), attrs)
            cur.ops.append(op)
            self.producer[name] = op
        if self.entry is None and self.comps:
            self.entry = list(self.comps)[-1]

    # ------------------------------------------------------------ pieces
    def _operand_bytes(self, op: Op) -> int:
        return sum(_shape_bytes(self.shape.get(o, "")) for o in op.operands)

    def _fusion_bytes(self, op: Op, comp_name: str) -> int:
        """HBM bytes for a fusion op, aware of slicing and in-place updates:

        * a parameter consumed only by dynamic-slice/gather counts the slice
          result sizes, not the full array (scan reading one layer's weights);
        * a parameter that is the in-place *target* of dynamic-update-slice /
          scatter counts zero (XLA aliases it), and the fusion result then
          counts only the update sizes (scan writing one layer's stash slot).
        """
        comp = self.comps.get(comp_name)
        if comp is None:
            return (_shape_bytes(op.result_text) + self._operand_bytes(op))
        param_names = comp.param_names
        direct: Dict[str, List[Op]] = {}
        for iop in comp.ops:
            for o in iop.operands:
                direct.setdefault(o, []).append(iop)

        _PASS = ("convert", "bitcast", "copy", "reshape", "transpose")

        def effective(name, depth=0):
            """Consumers, traced through elementwise/layout-only ops."""
            out = []
            for c in direct.get(name, []):
                if c.opcode in _PASS and depth < 6:
                    out += effective(c.name, depth + 1) or [c]
                else:
                    out.append(c)
            return out

        consumers = {n: effective(n) for n in
                     list(param_names.values())}

        producer = {iop.name: iop for iop in comp.ops}

        def src(name, depth=0):
            p = producer.get(name)
            while p is not None and p.opcode in _PASS and p.operands and depth < 8:
                p = producer.get(p.operands[0])
                depth += 1
            return p.name if p is not None else name

        # in-place targets: source of operand 0 of every dus/scatter
        inplace: Dict[str, int] = {}
        for iop in comp.ops:
            if iop.opcode in ("dynamic-update-slice", "scatter") and iop.operands:
                tgt = src(iop.operands[0])
                upd = (_shape_bytes(self.shape.get(iop.operands[1], ""))
                       if len(iop.operands) > 1 else 0)
                inplace[tgt] = inplace.get(tgt, 0) + upd

        total = 0
        has_inplace = False
        inplace_update_bytes = 0
        for idx, operand in enumerate(op.operands):
            full = _shape_bytes(self.shape.get(operand, ""))
            pname = param_names.get(idx)
            cons = consumers.get(pname, []) if pname else []
            if pname and pname in inplace and all(
                    c.opcode in ("dynamic-update-slice", "scatter")
                    for c in cons):
                has_inplace = True
                inplace_update_bytes += inplace[pname]
            elif cons and all(c.opcode in ("dynamic-slice", "gather")
                              for c in cons):
                total += min(full, sum(_shape_bytes(self.shape.get(c.name, ""))
                                       for c in cons))
            else:
                total += full
        if has_inplace:
            total += 2 * inplace_update_bytes      # write + (worst case) read
        else:
            total += _shape_bytes(op.result_text)
        return total

    def _dot_flops(self, op: Op) -> float:
        dt, dims = _shape_elems_first(op.result_text)
        if dims is None:
            return 0.0
        result_elems = 1
        for d in dims:
            result_elems *= d
        lhs_shape = self.shape.get(op.operands[0], "") if op.operands else ""
        _, lhs_dims = _shape_elems_first(lhs_shape)
        contract = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        if m and lhs_dims:
            for i in m.group(1).split(","):
                if i:
                    contract *= lhs_dims[int(i)]
        return 2.0 * result_elems * contract

    def _is_int8_dot(self, op: Op) -> bool:
        """An operand is int8 if it — or the value it was converted/laid out
        from — is s8/u8/s4/u4 (CPU XLA upcasts int8 operands with an explicit
        convert before the dot; TPU feeds the MXU int8 directly)."""
        _PASS = ("convert", "bitcast", "copy", "reshape", "transpose")
        for o in op.operands:
            name = o
            for _ in range(6):
                dt, _ = _shape_elems_first(self.shape.get(name, ""))
                if dt in ("s8", "u8", "s4", "u4"):
                    return True
                p = self.producer.get(name)
                if p is None or p.opcode not in _PASS or not p.operands:
                    break
                name = p.operands[0]
        return False

    @staticmethod
    def _trip_count(op: Op) -> int:
        m = re.search(r'known_trip_count=?\{"?n"?[:=]"?(\d+)"?\}', op.attrs)
        if m:
            return int(m.group(1))
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
        return int(m.group(1)) if m else 1

    @staticmethod
    def _attr_comp(op: Op, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w.\-]+)", op.attrs)
        return m.group(1) if m else None

    # ------------------------------------------------------------ main
    def cost(self, comp_name: Optional[str] = None) -> CostResult:
        name = comp_name or self.entry
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        res = CostResult()
        if comp is None:
            return res
        self._memo[name] = res
        count_bytes = not comp.is_fusion
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                f = self._dot_flops(op)
                res.flops += f
                if self._is_int8_dot(op):
                    res.int8_dot_flops += f
                if count_bytes:
                    res.bytes += (_shape_bytes(op.result_text)
                                  + self._operand_bytes(op))
            elif op.opcode == "while":
                body = self._attr_comp(op, "body")
                trips = self._trip_count(op)
                if body:
                    res.add(self.cost(body), max(trips, 1))
            elif op.opcode == "conditional":
                branch_names = re.search(r"branch_computations=\{([^}]*)\}",
                                         op.attrs)
                names = ([b.strip().lstrip("%") for b in
                          branch_names.group(1).split(",")]
                         if branch_names else
                         [c for c in (self._attr_comp(op, "true_computation"),
                                      self._attr_comp(op, "false_computation"))
                          if c])
                branches = [self.cost(b) for b in names]
                if branches:
                    res.add(max(branches, key=lambda r: r.flops + r.bytes))
            elif op.opcode in ("fusion", "call"):
                m = re.search(r"(?:calls|to_apply)=\{?%?([\w.\-]+)", op.attrs)
                if m:
                    sub = self.cost(m.group(1))
                    res.flops += sub.flops
                    res.int8_dot_flops += sub.int8_dot_flops
                    for c in COLLECTIVES:
                        res.coll_bytes[c] += sub.coll_bytes[c]
                        res.coll_counts[c] += sub.coll_counts[c]
                if count_bytes:
                    if m:
                        res.bytes += self._fusion_bytes(op, m.group(1))
                    else:
                        res.bytes += (_shape_bytes(op.result_text)
                                      + self._operand_bytes(op))
            elif any(op.opcode.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.opcode.startswith(c))
                if not op.opcode.endswith("-done"):
                    b = _shape_bytes(op.result_text)
                    res.coll_bytes[kind] += b
                    res.coll_counts[kind] += 1
                    if count_bytes:
                        res.bytes += 2 * b
            elif op.opcode == "dynamic-update-slice":
                if count_bytes and len(op.operands) > 1:
                    res.bytes += 2 * _shape_bytes(
                        self.shape.get(op.operands[1], ""))
            elif op.opcode == "dynamic-slice":
                if count_bytes:
                    res.bytes += 2 * _shape_bytes(op.result_text)
            elif op.opcode in _FREE_OPS:
                continue
            else:
                if count_bytes:
                    res.bytes += (_shape_bytes(op.result_text)
                                  + self._operand_bytes(op))
        return res


def analyze(hlo_text: str) -> CostResult:
    return HloCost(hlo_text).cost()
