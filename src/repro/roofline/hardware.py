"""Target hardware constants (TPU v5e, per chip)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str
    peak_bf16: float        # FLOP/s
    peak_int8: float        # OP/s
    hbm_bw: float           # B/s
    ici_bw: float           # B/s per link
    hbm_bytes: float
    vmem_bytes: float


TPU_V5E = Chip(
    name="tpu_v5e",
    peak_bf16=197e12,
    peak_int8=394e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
    vmem_bytes=128e6,
)
