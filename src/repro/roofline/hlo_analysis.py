"""Roofline terms from a compiled (SPMD-partitioned) XLA artifact.

  compute    = HLO_FLOPs_per_device / peak
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_result_bytes_per_device / ICI link bw

``cost_analysis`` yields per-device flops/bytes post-partitioning.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO and sum
the *result* shapes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (a slight overcount for reduce-scatter, undercount for
multi-hop all-gathers — consistent across variants, which is what the
hillclimb needs). Ops inside loops are multiplied by the trip count when the
while-loop bound is statically recoverable from scan structure.

The HLO text grammar (shape regex, dtype widths) is shared with the
compiled-plane invariant checker — ``analysis.hlo_core`` owns it.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from ..analysis.hlo_core import (DTYPE_BYTES, SHAPE_RE as _SHAPE_RE,
                                 shape_bytes as _shape_bytes)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _result_bytes(line: str) -> int:
    """Bytes of the op result: shapes between '=' and the op name."""
    try:
        lhs, rhs = line.split("=", 1)
    except ValueError:
        return 0
    # result type(s) = everything in rhs before the opcode token
    header = rhs.strip()
    # take shapes up to the first opcode occurrence
    for c in COLLECTIVES:
        idx = header.find(c + "(")
        if idx == -1:
            idx = header.find(c + "-start(")
        if idx != -1:
            header = header[:idx]
            break
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(header))


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _loop_trip_counts(text: str) -> Dict[str, int]:
    """Best-effort map from while-body computation name -> trip count."""
    trips: Dict[str, int] = {}
    # jax scans lower to while loops whose condition compares the induction
    # var against a constant: look for "compare(... constant)" patterns per
    # body. Fallback: trip count from "trip_count=" backend hints if present.
    for m in re.finditer(r"body=%?([\w.\-]+)", text):
        trips.setdefault(m.group(1), 1)
    return trips


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    count_by: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    # map computation -> multiplier (scan bodies execute trip_count times)
    comp_mult: Dict[str, int] = {}
    cur_comp = ""
    # first pass: find while-loop trip counts via induction-variable constants
    trip_re = re.compile(
        r"while\(.*\).*condition=%?([\w.\-]+).*body=%?([\w.\-]+)")
    cond_to_body = {}
    for line in hlo_text.splitlines():
        m = trip_re.search(line)
        if m:
            cond_to_body[m.group(1)] = m.group(2)
    # trip counts: constants compared in condition computations
    cond_const: Dict[str, int] = {}
    cur = None
    for line in hlo_text.splitlines():
        cm = re.match(r"%?([\w.\-]+) \(.*\) -> pred\[\]", line.strip())
        if cm:
            cur = cm.group(1)
        if cur and "constant(" in line:
            c = re.search(r"constant\((\d+)\)", line)
            if c:
                cond_const[cur] = max(cond_const.get(cur, 0), int(c.group(1)))
    body_trips = {body: cond_const.get(cond, 1)
                  for cond, body in cond_to_body.items()}

    for line in hlo_text.splitlines():
        s = line.strip()
        cm = re.match(r"%?([\w.\-]+) \([\w\s.,:\[\]\-]*\) -> ", s)
        if s.startswith("ENTRY") or cm:
            cur_comp = cm.group(1) if cm else "entry"
        for c in COLLECTIVES:
            if re.search(rf"= .*{c}(-start)?\(", s):
                mult = body_trips.get(cur_comp, 1)
                b = _result_bytes(s)
                bytes_by[c] += b * mult
                count_by[c] += mult
    return CollectiveStats(bytes_by, count_by)


def roofline_terms(cost: dict, coll: CollectiveStats, chip,
                   int8_fraction: float = 0.0) -> dict:
    """Three roofline terms (seconds, per device = per step wall-clock lower
    bound). ``int8_fraction``: share of matmul FLOPs running at the int8 MXU
    rate (HQP-quantized models)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    peak = (int8_fraction * chip.peak_int8
            + (1 - int8_fraction) * chip.peak_bf16)
    t_compute = flops / peak
    t_memory = byts / chip.hbm_bw
    t_coll = coll.total_bytes / chip.ici_bw
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dominant,
            "flops": flops, "bytes": byts,
            "collective_bytes": coll.total_bytes,
            "collective_counts": coll.count_by_kind}
