"""Compiled-plane invariant checks (analysis plane 1).

Each check lowers a declared hot path with representative abstract shapes
and walks the optimized HLO — the artifact that actually runs — instead
of trusting the source graph (HALP's argument, applied to our own stack):

  f32-roundtrip   no bf16 cache write lowered through an f32
                  ``dynamic-update-slice``/``scatter`` sandwich. This is
                  DESIGN.md §12 as a detector: XLA CPU float-normalization
                  rewrites bf16 stores through f32 converts, which
                  materializes a copy of the WHOLE arena on every write
                  (~4.8µs/page before PR 6/8 fixed it by storing raw
                  uint16 words). Matching is by result element count of
                  the protected cache leaves, not exact dims — the write
                  paths reshape the arena (``scatter_pages`` flattens
                  (pages, page_size) to one axis) but never change its
                  size, while every non-pathological f32 tensor in these
                  programs is activation-sized, orders of magnitude
                  smaller than an arena.
  donation        every leaf of a declared-donated argument appears in
                  the executable's ``input_output_alias`` map. A donation
                  silently dropped (dtype drift, an accidental copy)
                  doubles peak KV memory without failing any test.
  host-syncs      the count of host boundary ops (infeed/outfeed/send/
                  recv/host-callback custom-calls) inside the compiled
                  body is ``declared - 1``: fetching the dispatch result
                  is always one sync, and the body must not hide more.
  retrace-budget  after a scripted workload, the number of distinct
                  compiled variants of each hot path stays within the
                  declared window-bucketing bound (``max_seq /
                  SchedulerConfig.window_block``) — the guard against a
                  dynamic shape sneaking into a static argument.

Scenarios cover the KV matrix the engine actually serves: {bf16, INT8 KV}
x {contiguous, paged}, plus the speculative dual-pool path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hlo_core
from .invariants import REGISTRY, InvariantSpec, spec_of
from .report import Violation

# opcodes that cross the host boundary; host callbacks lower to
# custom-calls whose target names the python callback trampoline
HOST_BOUNDARY_OPCODES = ("infeed", "outfeed", "send", "recv")
HOST_CALLBACK_MARKERS = ("python_cpu_callback", "python_gpu_callback",
                         "callback_custom_call", "xla_ffi_python")

CACHE_WRITE_OPCODES = ("dynamic-update-slice", "scatter")


def _elem_count(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


# --------------------------------------------------------- low-level checks
def f32_roundtrip_violations(hlo_text: str,
                             protected_counts: Sequence[int]) -> List[str]:
    """f32 cache-write instructions whose result is exactly the size of a
    protected (non-f32) cache leaf — the §12 float-normalization sandwich.
    ``protected_counts``: element counts of every cache leaf that must
    never round-trip through f32 (bf16/uint16/int8 storage)."""
    protected = set(protected_counts)
    out = []
    for ins in hlo_core.parse_instructions(hlo_text):
        if (ins.opcode in CACHE_WRITE_OPCODES and ins.dtype == "f32"
                and _elem_count(ins.dims) in protected and ins.dims):
            out.append(
                f"f32 {ins.opcode} over a protected cache buffer "
                f"(shape f32[{','.join(map(str, ins.dims))}] in "
                f"%{ins.computation}): bf16 storage is round-tripping "
                f"through float-normalization — store raw uint16 words "
                f"instead (kernels.kv_layout.to_store)")
    return out


def donation_violations(hlo_text: str,
                        expected_shapes: Sequence[str]) -> List[str]:
    """Donated leaves with no matching entry in ``input_output_alias``.

    ``expected_shapes``: one canonical ``dtype[dims]`` string per donated
    leaf (multiplicity matters — a pool with two u16[...] KV leaves needs
    two aliased u16[...] params). Matching is by shape rather than param
    number because jit's ``keep_unused=False`` default prunes unused
    arguments from the executable, shifting every later param number."""
    params = hlo_core.parse_entry_params(hlo_text)
    aliased: Dict[str, int] = {}
    for p in hlo_core.aliased_param_numbers(hlo_text):
        if p < len(params):
            aliased[params[p]] = aliased.get(params[p], 0) + 1
    out = []
    for shape in expected_shapes:
        if aliased.get(shape, 0) > 0:
            aliased[shape] -= 1
        else:
            out.append(
                f"donated leaf {shape} absent from input_output_alias — "
                f"the executable copies instead of updating in place")
    return out


def host_sync_violations(hlo_text: str, host_syncs: int) -> List[str]:
    """Host boundary ops in the body vs the declared budget (the result
    fetch itself is the one sync a budget of 1 allows)."""
    hits = []
    for ins in hlo_core.parse_instructions(hlo_text):
        if ins.opcode in HOST_BOUNDARY_OPCODES:
            hits.append(ins.opcode)
        elif ins.opcode == "custom-call" and any(
                m in ins.raw for m in HOST_CALLBACK_MARKERS):
            hits.append("host-callback")
    allowed = host_syncs - 1
    if len(hits) > allowed:
        return [
            f"{len(hits)} host boundary op(s) in the compiled body "
            f"({', '.join(hits)}) but the declared budget of "
            f"host_syncs={host_syncs} allows {allowed} beyond the result "
            f"fetch"]
    return []


# ------------------------------------------------------- lowering machinery
def abstractify(tree):
    """Concrete pytree -> ShapeDtypeStructs (lowering needs shapes only,
    not a second live copy of an engine pool)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


# numpy dtype name -> HLO element-type token (parse_entry_params canon)
_HLO_DTYPE = {
    "float32": "f32", "float64": "f64", "float16": "f16",
    "bfloat16": "bf16", "int8": "s8", "int16": "s16", "int32": "s32",
    "int64": "s64", "uint8": "u8", "uint16": "u16", "uint32": "u32",
    "uint64": "u64", "bool": "pred",
}


def _hlo_shape(x) -> str:
    dt = _HLO_DTYPE.get(jnp.result_type(x).name, jnp.result_type(x).name)
    return f"{dt}[{','.join(str(d) for d in jnp.shape(x))}]"


def donated_leaf_shapes(args: Sequence, spec: InvariantSpec) -> List[str]:
    """Canonical ``dtype[dims]`` string per leaf of the spec's donated
    arguments (one entry per leaf — multiplicity carries through to the
    alias-map multiset check)."""
    out: List[str] = []
    for pos in spec.donated_positions():
        out += [_hlo_shape(l) for l in jax.tree.leaves(args[pos])]
    return out


def lower_hlo(fn, args: Sequence, spec: InvariantSpec) -> str:
    """Optimized HLO text for ``fn(*args)`` — static args stay concrete,
    dynamic args are abstracted to shapes."""
    lowered_args = [a if i in set(spec.static_argnums) else abstractify(a)
                    for i, a in enumerate(args)]
    return fn.lower(*lowered_args).compile().as_text()


def check_callable(fn, args: Sequence, *, where: str,
                   protected_counts: Sequence[int] = (),
                   spec: Optional[InvariantSpec] = None) -> List[Violation]:
    """Run every HLO-plane check the callable's spec declares."""
    spec = spec or spec_of(fn)
    if spec is None:
        return [Violation("hlo", "no-spec", where,
                          "callable has no declared invariants")]
    text = lower_hlo(fn, args, spec)
    out: List[Violation] = []
    if spec.forbid_f32_roundtrip_on:
        out += [Violation("hlo", "f32-roundtrip", where, m)
                for m in f32_roundtrip_violations(text, protected_counts)]
    if spec.donated:
        expected = donated_leaf_shapes(args, spec)
        out += [Violation("hlo", "donation", where, m)
                for m in donation_violations(text, expected)]
    if spec.host_syncs is not None:
        out += [Violation("hlo", "host-syncs", where, m)
                for m in host_sync_violations(text, spec.host_syncs)]
    return out


# ------------------------------------------------------- engine scenarios
def kv_leaf_counts(pool: dict) -> List[int]:
    """Element counts of every non-f32 KV-cache leaf (f32 leaves are the
    INT8 path's dequant scales — those legitimately update in f32)."""
    from ..serving import state_pool as sp
    counts = []
    for entry in pool["caches"]:
        if sp.is_kv_entry(entry):
            counts += [_elem_count(tuple(l.shape))
                       for l in jax.tree.leaves(entry)
                       if l.dtype != jnp.float32]
    return counts


def _i32(shape=()):
    return jnp.zeros(shape, jnp.int32)


def engine_hot_paths(eng) -> Dict[str, Tuple[Callable, tuple]]:
    """name -> (jitted fn, representative concrete args). Args mirror the
    engine's own dispatch sites; the checker abstracts the dynamic ones."""
    table = jnp.asarray(eng.table)
    chunk = _i32((1, eng.scheduler.cfg.prefill_chunk))
    win_pre = eng._window(eng.scheduler.cfg.prefill_chunk)
    win_dec = eng._window(
        eng.scheduler.cfg.prefill_chunk + eng.scheduler.cfg.decode_steps)
    b = eng.n_slots
    tokens, active = _i32((b, 1)), jnp.zeros((b,), bool)
    eos, budget = _i32((b,)), _i32((b,))
    paths = {
        "engine.reset": (eng._reset_fn,
                         (eng.pool, _i32(), eng._template, _i32())),
        "engine.prefill": (eng._prefill_fn,
                           (eng.params, eng.pool, table, _i32(), chunk,
                            win_pre)),
        "engine.decode": (eng._decode_fn,
                          (eng.params, eng.pool, table, tokens, active,
                           eos, budget, win_dec)),
    }
    if eng.paged:
        dpool = eng.draft_pool if eng.spec is not None else None
        paths["engine.copy_page"] = (
            eng._copy_page_fn, (eng.pool, dpool, _i32(), _i32()))
    if eng.spec is not None:
        paths["engine.spec_prefill"] = (
            eng._spec_prefill_fn,
            (eng.spec.draft_params, eng.params, eng.draft_pool, eng.pool,
             table, _i32(), chunk, win_pre))
        paths["engine.spec"] = (
            eng.spec.spec_fn,
            (eng.spec.draft_params, eng.params, eng.draft_pool, eng.pool,
             table, tokens, tokens, active, eos, budget, eng.spec.k,
             eng.spec.cycles, win_dec))
    return paths


def check_engine(eng, scenario: str) -> List[Violation]:
    protected = kv_leaf_counts(eng.pool)
    if eng.spec is not None:
        protected = protected + kv_leaf_counts(eng.draft_pool)
    out: List[Violation] = []
    for name, (fn, args) in engine_hot_paths(eng).items():
        out += check_callable(fn, args, where=f"{name}[{scenario}]",
                              protected_counts=protected)
    return out


def check_retrace(eng, scenario: str, *,
                  prompt_lens: Sequence[int] = (5, 9, 17, 23, 31),
                  max_new: int = 8, seed: int = 0) -> List[Violation]:
    """Drive a scripted workload spanning several window buckets, then
    compare each hot path's distinct-lowering count to its declared
    ``max_lowerings`` (the ``max_seq / window_block`` bound)."""
    from ..serving import Request
    rng = np.random.RandomState(seed)
    vocab = eng.cfg.vocab_size
    reqs = [Request(prompt=rng.randint(0, vocab, n).tolist(),
                    max_new_tokens=max_new) for n in prompt_lens]
    eng.run(reqs, arrival_ticks=list(range(0, 3 * len(reqs), 3)))
    out: List[Violation] = []
    for name, (fn, _) in engine_hot_paths(eng).items():
        spec = spec_of(fn)
        if spec is None or spec.max_lowerings is None:
            continue
        size = getattr(fn, "_cache_size", lambda: None)()
        if size is None:
            continue    # older jax without the introspection hook
        if size > spec.max_lowerings:
            out.append(Violation(
                "hlo", "retrace-budget", f"{name}[{scenario}]",
                f"{size} distinct lowerings after the scripted workload, "
                f"declared max_lowerings={spec.max_lowerings} "
                f"(max_seq/window_block bucketing bound) — a dynamic "
                f"shape is leaking into a static argument"))
    return out


# --------------------------------------------------------------- driver API
def build_scenario(quantized_kv: bool, paged: bool, *, speculative=False,
                   arch: str = "qwen3-0.6b", n_slots: int = 2,
                   max_seq: int = 64, page_size: int = 8):
    """A small live engine for one cell of the KV matrix."""
    from .. import configs
    from ..models import lm
    from ..serving import Engine
    from ..sharding.ctx import default_ctx
    cfg = configs.get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ctx = dataclasses.replace(default_ctx(), quantized_kv=quantized_kv)
    kw = dict(ctx=ctx, n_slots=n_slots, max_seq=max_seq)
    if paged:
        kw["page_size"] = page_size
    if speculative:
        from ..compress import compress
        art = compress(params, cfg, log=lambda s: None)
        kw.update(draft_params=art.params, draft_ctx=ctx,
                  draft_manifest=art.manifest)
    return Engine(params, cfg, **kw)


def scenario_name(quantized_kv: bool, paged: bool, speculative=False) -> str:
    return "+".join(["int8" if quantized_kv else "bf16",
                     "paged" if paged else "contig"]
                    + (["spec"] if speculative else []))


def run_hlo_plane(log=print) -> List[Violation]:
    """The full compiled-plane sweep ``scripts/check_static.py`` runs."""
    out: List[Violation] = []
    for quantized_kv in (False, True):
        for paged in (False, True):
            name = scenario_name(quantized_kv, paged)
            log(f"[hlo] scenario {name}: lowering declared hot paths")
            eng = build_scenario(quantized_kv, paged)
            out += check_engine(eng, name)
    # speculative dual-pool cell (spec_fn + fused spec prefill)
    name = scenario_name(True, False, speculative=True)
    log(f"[hlo] scenario {name}: lowering declared hot paths")
    eng = build_scenario(True, False, speculative=True)
    out += check_engine(eng, name)
    # retrace budget: one paged + one contiguous workload
    for paged in (False, True):
        name = scenario_name(False, paged)
        log(f"[hlo] scenario {name}: scripted retrace-budget workload")
        eng = build_scenario(False, paged)
        out += check_retrace(eng, name)
    return out
