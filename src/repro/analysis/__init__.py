"""Static analysis over the serving stack (DESIGN.md §15).

Two planes, one driver (``scripts/check_static.py``), CI-gated:

  * plane 1 — compiled artifact: ``invariants.declare_invariants`` lets a
    jitted hot path declare what its optimized HLO must look like
    (host-sync budget, donated-pool aliasing, no f32 round-trip on bf16
    cache stores, retrace budget); ``hlo_checks`` lowers each declared
    path with representative shapes and enforces the claims against
    ``compiled.as_text()``. ``hlo_core`` is the shared HLO text parser
    (also the roofline analyzer's).
  * plane 2 — source: ``astlint`` checks serving-discipline rules the
    type system can't express (injectable clocks, single-owner pump,
    no host syncs inside jit, bench-gate messages, deduped helpers).
"""
from .invariants import REGISTRY, InvariantSpec, declare_invariants, spec_of
from .report import Violation, render

__all__ = ["REGISTRY", "InvariantSpec", "declare_invariants", "spec_of",
           "Violation", "render"]
