"""Violation records shared by both analysis planes.

One shape for everything ``scripts/check_static.py`` prints and gates on:
the HLO plane reports against a (hot-path name, scenario) coordinate, the
AST plane against a (file, line) coordinate — both collapse to the same
record so the driver needs exactly one "any violations -> exit 1" loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Violation:
    plane: str              # "hlo" | "ast"
    rule: str               # e.g. "f32-roundtrip", "no-raw-clock"
    where: str              # "engine.decode[int8+paged]" or "path/file.py"
    message: str
    line: Optional[int] = None

    def __str__(self) -> str:
        loc = f"{self.where}:{self.line}" if self.line else self.where
        return f"[{self.plane}:{self.rule}] {loc}: {self.message}"


def render(violations: List[Violation]) -> str:
    if not violations:
        return "static checks: OK (0 violations)"
    lines = [str(v) for v in violations]
    lines.append(f"static checks: {len(violations)} violation(s)")
    return "\n".join(lines)
