"""Shared parser over optimized HLO text (``compiled.as_text()``).

Two consumers read the compiled artifact today and must agree on how it
parses: ``roofline.hlo_analysis`` (collective result bytes per device) and
``analysis.hlo_checks`` (the compiled-plane invariant checker). Both walk
the same line-oriented HLO dump, so the instruction grammar lives here
once: per-instruction records across EVERY computation — XLA's fusion pass
hides the interesting ops (the bf16 ``dynamic-update-slice`` f32 sandwich,
callback custom-calls) inside ``%fused_computation`` bodies, so an
ENTRY-only walk misses exactly the instructions the checks exist to find.

The text format is stable enough for this: one instruction per line,
``[ROOT] %name = shape opcode(operands), attrs``, with computations opened
by ``comp_name (params) -> result {`` headers. Lines that do not parse are
skipped, never fatal — the checks are written so a parse miss can only
produce a false PASS on an op we failed to see, and the seeded-violation
tests in ``tests/test_static_analysis.py`` pin that the ops we care about
do parse on the jax version in CI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# `%name = shape opcode(` — shape is a single `dtype[dims]{layout}` or a
# tuple `(shape, shape, ...)`; opcode is the token just before the operand
# paren. Tuples never nest parens in practice for the ops we inspect.
_INSTR_RE = re.compile(
    r"^(ROOT\s+)?%?([\w.\-]+)\s+=\s+"
    r"(\([^)]*\)|[\w\[\],{}:]+)\s+"
    r"([\w\-]+)\(")

# computation headers: `%name (args) -> result {` (ENTRY has its own form)
_COMP_RE = re.compile(r"^%?([\w.\-]+)\s+\([^)]*\)\s*->")

_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass(frozen=True)
class Instruction:
    name: str                    # result name, no leading %
    dtype: str                   # result element type ("" for tuples)
    dims: Tuple[int, ...]        # result dims (() for scalars and tuples)
    opcode: str
    operands: Tuple[str, ...]    # operand instruction names, no leading %
    computation: str             # enclosing computation ("entry" for ENTRY)
    raw: str                     # the stripped source line


def _result_shape(shape_text: str) -> Tuple[str, Tuple[int, ...]]:
    """First (dtype, dims) of the result spec; tuples report ("", ())."""
    if shape_text.startswith("("):
        return "", ()
    m = SHAPE_RE.match(shape_text)
    if not m:
        return shape_text, ()        # scalar like `pred[]` misses dims only
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def parse_instructions(hlo_text: str) -> List[Instruction]:
    """Every instruction in every computation, fusion bodies included."""
    out: List[Instruction] = []
    comp = "entry"
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            comp = "entry"
            continue
        cm = _COMP_RE.match(s)
        if cm and "=" not in s.split("(")[0]:
            comp = cm.group(1)
            continue
        im = _INSTR_RE.match(s)
        if not im:
            continue
        _, name, shape_text, opcode = im.groups()
        dtype, dims = _result_shape(shape_text)
        # operand region: from the opcode's '(' to the attr tail; operand
        # refs always carry '%' in as_text, attrs (metadata, calls) may too
        # — cut at "), " which closes the operand list in practice
        args = s[im.end():]
        cut = args.find("), ")
        if cut != -1:
            args = args[:cut]
        operands = tuple(_OPERAND_RE.findall(args))
        out.append(Instruction(name=name, dtype=dtype, dims=dims,
                               opcode=opcode, operands=operands,
                               computation=comp, raw=s))
    return out


_ALIAS_BLOCK_RE = re.compile(r"input_output_alias=\{(.*?)\}(?:,|\s|$)")
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\},\s*([\w\-]+)\)")


def parse_io_aliases(hlo_text: str) -> List[Tuple[Tuple[int, ...], int]]:
    """(output_index, param_number) pairs from the HloModule header's
    ``input_output_alias={ {0}: (1, {}, may-alias), ... }`` block. Under
    jit every pytree leaf is its own flat parameter, so ``param_index``
    is always ``{}`` and the param_number alone identifies the donated
    leaf."""
    header = hlo_text.splitlines()[0] if hlo_text else ""
    start = header.find("input_output_alias={")
    if start == -1:
        return []
    # the block nests braces ({ {0}: (0, {}, ...) }) — scan to its close
    i = start + len("input_output_alias=")
    depth, j = 0, i
    for j, ch in enumerate(header[i:], i):
        depth += (ch == "{") - (ch == "}")
        if depth == 0:
            break
    block = header[i:j + 1]
    out = []
    for om, pnum, _pidx, _kind in _ALIAS_ENTRY_RE.findall(block):
        oidx = tuple(int(x) for x in om.replace(" ", "").split(",") if x)
        out.append((oidx, int(pnum)))
    return out


def aliased_param_numbers(hlo_text: str) -> set:
    return {p for _, p in parse_io_aliases(hlo_text)}


def parse_entry_params(hlo_text: str) -> List[str]:
    """Canonical ``dtype[d0,d1,...]`` strings for the executable's entry
    parameters, in param-number order, from the HloModule header's
    ``entry_computation_layout={(p0, p1, ...)->(...)}``. This is the
    ground truth for which python-level leaves survived into the
    executable — jit's ``keep_unused=False`` default PRUNES arguments XLA
    proves unused, so positional prefix sums over the python args do not
    index this list safely; match by shape instead."""
    header = hlo_text.splitlines()[0] if hlo_text else ""
    start = header.find("entry_computation_layout={(")
    if start == -1:
        return []
    i = start + len("entry_computation_layout={")
    arrow = header.find(")->", i)
    if arrow == -1:
        return []
    return [f"{dt}[{dims}]"
            for dt, dims in SHAPE_RE.findall(header[i:arrow + 1])]


def count_opcode(instrs: List[Instruction], opcode: str) -> int:
    return sum(1 for i in instrs if i.opcode == opcode)


def index_by_name(instrs: List[Instruction]) -> Dict[str, Instruction]:
    """name -> instruction. Names repeat across computations in some
    dumps; the checks only chase operands within one computation, so
    later computations overwriting earlier entries is acceptable — we
    index per-computation where it matters."""
    return {i.name: i for i in instrs}
