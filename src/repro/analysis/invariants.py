"""Declared invariants for jitted hot paths.

The serving stack's hardest-won properties — one host sync per decode
dispatch, donated pools updated in place, bf16 KV stored as raw uint16
words, bounded retracing under window bucketing — are invisible to unit
tests: they live in the *compiled* artifact, not in token output. The
``declare_invariants`` decorator lets the code that builds a jitted hot
path say, next to the ``jax.jit`` call, what the compiled artifact must
look like; ``analysis.hlo_checks`` later lowers the callable with
representative shapes and walks the optimized HLO to enforce each claim.

Usage (engine.py)::

    self._decode_fn = declare_invariants(
        "engine.decode", host_syncs=1, donated=("pool",),
        forbid_f32_roundtrip_on=("kv",),
        max_lowerings=max_seq // window_block,
    )(jax.jit(_decode, donate_argnums=(1,), static_argnums=(7,)))

Spec fields (all optional):

  host_syncs            total host round-trips one dispatch may cost. The
                        result fetch is always one, so the compiled HLO
                        must contain exactly ``host_syncs - 1`` host
                        boundary ops (infeed/outfeed/send/recv/host
                        callback custom-calls).
  donated               names of python-level arguments whose every leaf
                        must show up in the executable's
                        ``input_output_alias`` map (no full-arena copy).
  forbid_f32_roundtrip_on  names of cache families (today: "kv") whose
                        storage writes must never lower to an f32
                        ``dynamic-update-slice``/``scatter`` — the §12
                        bug class (XLA CPU float-normalization rewrites
                        bf16 stores through f32 converts, copying the
                        whole buffer per write).
  max_lowerings         cap on distinct compiled variants after a
                        scripted workload (the window-bucketing bound).

The decorator records the spec in a module-level registry (name -> spec;
specs only — never the callable, which would pin a whole engine's pools
live) and, where the callable object allows it, mirrors the spec onto the
function as ``__repro_invariants__`` so a debugger can see it in place.
Re-registration under the same name overwrites: every Engine constructs
fresh jitted closures, and the last-built engine's declaration is the one
a checker run against that engine must see.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class InvariantSpec:
    name: str
    host_syncs: Optional[int] = None
    donated: Tuple[str, ...] = ()
    forbid_f32_roundtrip_on: Tuple[str, ...] = ()
    max_lowerings: Optional[int] = None
    arg_names: Tuple[str, ...] = ()
    static_argnums: Tuple[int, ...] = ()

    def donated_positions(self) -> Tuple[int, ...]:
        """Positional indices (python signature order) of donated args."""
        return tuple(self.arg_names.index(n) for n in self.donated)


REGISTRY: Dict[str, InvariantSpec] = {}


def declare_invariants(name: str, *, host_syncs: Optional[int] = None,
                       donated: Tuple[str, ...] = (),
                       forbid_f32_roundtrip_on: Tuple[str, ...] = (),
                       max_lowerings: Optional[int] = None,
                       static_argnums: Tuple[int, ...] = ()):
    """Attach an :class:`InvariantSpec` to a jitted callable and record it
    under ``name`` in the module registry. Returns the callable unchanged —
    zero runtime cost on the hot path."""
    def wrap(fn):
        inner = getattr(fn, "__wrapped__", fn)
        try:
            arg_names = tuple(inspect.signature(inner).parameters)
        except (TypeError, ValueError):
            arg_names = ()
        for n in donated:
            if arg_names and n not in arg_names:
                raise ValueError(
                    f"declare_invariants({name!r}): donated arg {n!r} not "
                    f"in signature {arg_names}")
        spec = InvariantSpec(name=name, host_syncs=host_syncs,
                             donated=tuple(donated),
                             forbid_f32_roundtrip_on=tuple(
                                 forbid_f32_roundtrip_on),
                             max_lowerings=max_lowerings,
                             arg_names=arg_names,
                             static_argnums=tuple(static_argnums))
        REGISTRY[name] = spec
        try:
            fn.__repro_invariants__ = spec
        except (AttributeError, TypeError):
            pass    # C-implemented callables without a __dict__ still work
        return fn
    return wrap


def spec_of(fn) -> Optional[InvariantSpec]:
    return getattr(fn, "__repro_invariants__", None)
