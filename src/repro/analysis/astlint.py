"""Repo-specific AST lint (analysis plane 2). stdlib ``ast`` only.

Six rules, each encoding a serving-stack discipline that an ordinary
linter cannot know about:

  no-raw-clock              a ``serving/`` module that declares an
                            injectable ``clock`` parameter must not call
                            ``time.time()``/``time.monotonic()`` — raw
                            clock reads bypass the injection point that
                            makes deadline tests deterministic.
  pump-single-owner         ``service.py`` HTTP handler scope (``async
                            def``) must not CALL methods through
                            ``self.service...``/``...engine...`` — the
                            pump thread is the single owner of engine and
                            service state; handlers talk to it via the
                            inbox (``self._ask``/``self._inbox.append``).
                            Attribute READS stay allowed.
  no-host-sync-in-hot-path  functions handed to ``jax.jit`` must not call
                            ``np.asarray``/``int()``/``float()``/
                            ``.item()`` — each is a device sync that
                            breaks the one-host-sync-per-dispatch budget.
  bench-gate-message        ``scripts/check_bench.py`` gates must not use
                            bare ``assert`` without a measured-vs-
                            threshold message (a bare assert fails CI
                            with no number to debug from).
  duplicate-hot-path-helper the host-side greedy-argmax fallback
                            ``int(np.argmax(np.asarray(...)))`` may
                            appear in at most one function per module —
                            the copy-paste that let two emission paths
                            drift apart.
  stats-schema              any ``stats["key"]`` written in ``serving/``
                            (subscript assignment or a ``self.stats =
                            {...}`` dict literal) must be declared in
                            ``repro.telemetry.schema`` — ``GET /metrics``
                            renders every stats key, so an undeclared key
                            would silently fall off the exposition (the
                            registry raises at Service construction, but
                            only on the code path that runs; the lint
                            catches every write site statically).

Escape hatch: append ``# repro-lint: disable=<rule>[,<rule>...]`` (or
``disable=all``) to the flagged line. Every disable is deliberate and
greppable. (The watchdog heartbeat's wall-clock reads no longer need
one: they go through ``repro.telemetry.clock.wall_clock``, the single
sanctioned raw-clock helper, instead of per-site escapes.)
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .report import Violation

RULES = ("no-raw-clock", "pump-single-owner", "no-host-sync-in-hot-path",
         "bench-gate-message", "duplicate-hot-path-helper", "stats-schema")

_DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w\-,\s]+)")

# pump-single-owner: attribute segments that mark pump-owned state, and
# self-rooted call chains handlers may use (the inbox protocol)
_OWNED_SEGMENTS = ("service", "engine")
_INBOX_WHITELIST = (("self", "_ask"), ("self", "_inbox", "append"))

_RAW_CLOCK_CALLS = (("time", "time"), ("time", "monotonic"))


def _disabled_rules(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for lineno, line in enumerate(source.splitlines(), 1):
        m = _DISABLE_RE.search(line)
        if m:
            out[lineno] = {r.strip() for r in m.group(1).split(",")
                           if r.strip()}
    return out


def _attr_chain(node: ast.AST) -> Tuple[str, ...]:
    """x.a.b.c -> ("x", "a", "b", "c"); non-name roots yield ("?", ...)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    parts.append(node.id if isinstance(node, ast.Name) else "?")
    return tuple(reversed(parts))


def _functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _declares_clock_param(tree: ast.AST) -> bool:
    for fn in _functions(tree):
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            if a.arg == "clock":
                return True
    return False


# ----------------------------------------------------------------- rules
def _rule_no_raw_clock(tree: ast.AST) -> List[Tuple[int, str]]:
    if not _declares_clock_param(tree):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and _attr_chain(node.func) in _RAW_CLOCK_CALLS:
            out.append((node.lineno,
                        f"raw {'.'.join(_attr_chain(node.func))}() in a "
                        f"module that declares an injectable clock — "
                        f"thread the clock parameter through instead"))
    return out


def _rule_pump_single_owner(tree: ast.AST) -> List[Tuple[int, str]]:
    out = []
    for fn in _functions(tree):
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain in _INBOX_WHITELIST:
                continue
            if chain[0] == "self" and any(s in chain[1:-1]
                                          for s in _OWNED_SEGMENTS):
                out.append((
                    node.lineno,
                    f"handler scope calls {'.'.join(chain)}() — engine/"
                    f"service state is pump-owned; post to the inbox "
                    f"(self._ask / self._inbox.append) instead"))
    return out


def _jitted_function_names(tree: ast.AST) -> Set[str]:
    """Names of local functions passed to jax.jit(<name>, ...)."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and node.args \
                and _attr_chain(node.func)[-1] == "jit" \
                and _attr_chain(node.func)[0] in ("jax", "jit"):
            if isinstance(node.args[0], ast.Name):
                names.add(node.args[0].id)
    return names


def _rule_no_host_sync(tree: ast.AST) -> List[Tuple[int, str]]:
    hot = _jitted_function_names(tree)
    if not hot:
        return []
    out = []
    for fn in _functions(tree):
        if fn.name not in hot:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            sync = None
            if chain in (("np", "asarray"), ("numpy", "asarray")):
                sync = "np.asarray"
            elif chain in (("int",), ("float",)):
                sync = f"{chain[0]}()"
            elif chain[-1] == "item" and len(chain) > 1:
                sync = ".item()"
            if sync:
                out.append((
                    node.lineno,
                    f"{sync} inside jitted hot path {fn.name!r} forces a "
                    f"device sync — keep host conversions outside the "
                    f"jit boundary"))
    return out


def _rule_bench_gate_message(tree: ast.AST) -> List[Tuple[int, str]]:
    return [
        (node.lineno,
         "bare assert in a bench gate — include the measured value and "
         "threshold in the message (or raise via fail())")
        for node in ast.walk(tree)
        if isinstance(node, ast.Assert) and node.msg is None]


def _is_argmax_fallback(node: ast.AST) -> bool:
    """int(np.argmax(np.asarray(...)))"""
    if not (isinstance(node, ast.Call) and _attr_chain(node.func) == ("int",)
            and node.args):
        return False
    inner = node.args[0]
    if not (isinstance(inner, ast.Call)
            and _attr_chain(inner.func)[-1] == "argmax" and inner.args):
        return False
    arg = inner.args[0]
    return (isinstance(arg, ast.Call)
            and _attr_chain(arg.func)[-1] == "asarray")


def _rule_duplicate_helper(tree: ast.AST) -> List[Tuple[int, str]]:
    sites: List[Tuple[str, int]] = []
    for fn in _functions(tree):
        for node in ast.walk(fn):
            if _is_argmax_fallback(node):
                sites.append((fn.name, node.lineno))
                break           # one hit per function is enough
    if len({name for name, _ in sites}) <= 1:
        return []
    return [
        (line,
         f"greedy-argmax fallback duplicated in {fn!r} — "
         f"{len(sites)} functions in this module carry the same "
         f"int(np.argmax(np.asarray(...))) pattern; share one helper")
        for fn, line in sites]


def _declared_stat_keys() -> Optional[frozenset]:
    """The telemetry schema's declared stats keys, or None when the
    schema is unimportable (a bare checkout linting fixture snippets —
    the rule then reports nothing rather than everything)."""
    try:
        from repro.telemetry.schema import DECLARED_STAT_KEYS
        return DECLARED_STAT_KEYS
    except Exception:
        return None


def _rule_stats_schema(tree: ast.AST) -> List[Tuple[int, str]]:
    declared = _declared_stat_keys()
    if declared is None:
        return []
    out = []

    def flag(lineno: int, key: str) -> None:
        out.append((
            lineno,
            f"stats key {key!r} is not declared in repro.telemetry.schema "
            f"— GET /metrics renders every stats key, so declare it "
            f"(kind + help) in ENGINE_STATS/SERVICE_STATS or it falls off "
            f"the exposition"))

    for node in ast.walk(tree):
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target] if isinstance(node, ast.AugAssign)
                   else [])
        for t in targets:
            # stats["key"] = / += writes with a literal key
            if (isinstance(t, ast.Subscript)
                    and _attr_chain(t.value)[-1] == "stats"
                    and isinstance(t.slice, ast.Constant)
                    and isinstance(t.slice.value, str)
                    and t.slice.value not in declared):
                flag(t.lineno, t.slice.value)
            # self.stats = {...} dict-literal initializers
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Dict)
                    and isinstance(t, (ast.Attribute, ast.Name))
                    and _attr_chain(t)[-1] == "stats"):
                for k in node.value.keys:
                    if (isinstance(k, ast.Constant)
                            and isinstance(k.value, str)
                            and k.value not in declared):
                        flag(k.lineno, k.value)
    return out


# ----------------------------------------------------------------- driver
def rules_for(filename: str) -> Tuple[str, ...]:
    """Which rules apply to a file, by its repo-relative path."""
    p = pathlib.PurePosixPath(str(filename).replace("\\", "/"))
    out: List[str] = []
    if "serving" in p.parts:
        out += ["no-raw-clock", "no-host-sync-in-hot-path",
                "duplicate-hot-path-helper", "stats-schema"]
        if p.name == "service.py":
            out.append("pump-single-owner")
    if p.name == "check_bench.py":
        out.append("bench-gate-message")
    return tuple(out)


_RULE_FNS = {
    "no-raw-clock": _rule_no_raw_clock,
    "pump-single-owner": _rule_pump_single_owner,
    "no-host-sync-in-hot-path": _rule_no_host_sync,
    "bench-gate-message": _rule_bench_gate_message,
    "duplicate-hot-path-helper": _rule_duplicate_helper,
    "stats-schema": _rule_stats_schema,
}


def lint_source(source: str, filename: str,
                rules: Optional[Iterable[str]] = None) -> List[Violation]:
    """Lint one module's source. ``rules=None`` selects by filename
    (``rules_for``); tests pass explicit rules against fixture snippets."""
    selected = tuple(rules) if rules is not None else rules_for(filename)
    if not selected:
        return []
    tree = ast.parse(source, filename=str(filename))
    disabled = _disabled_rules(source)
    out: List[Violation] = []
    for rule in selected:
        for lineno, msg in _RULE_FNS[rule](tree):
            d = disabled.get(lineno, ())
            if rule in d or "all" in d:
                continue
            out.append(Violation("ast", rule, str(filename), msg,
                                 line=lineno))
    return sorted(out, key=lambda v: (v.where, v.line or 0, v.rule))


def default_targets(root) -> List[pathlib.Path]:
    root = pathlib.Path(root)
    targets = sorted((root / "src/repro/serving").glob("*.py"))
    bench = root / "scripts/check_bench.py"
    if bench.exists():
        targets.append(bench)
    return targets


def lint_tree(root) -> List[Violation]:
    root = pathlib.Path(root)
    out: List[Violation] = []
    for path in default_targets(root):
        rel = path.relative_to(root).as_posix()
        out += lint_source(path.read_text(), rel)
    return out
