"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Each 8-layer Jamba block has 1 attention + 7 Mamba layers (attention in the
middle of the block); MoE replaces the MLP every 2 layers. Sub-quadratic
(Mamba state decode): runs long_500k.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

ARCH_ID = "jamba-1.5-large-398b"


def _pattern(n_layers: int):
    # 1:7 attn:mamba — attention at position 4 of every 8-layer block.
    return tuple("attn" if (i % 8 == 4) else "mamba" for i in range(n_layers))


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        block_pattern=_pattern(72),
        moe=MoEConfig(n_experts=16, experts_per_token=2, moe_every=2, moe_offset=1),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        subquadratic=True,
        max_seq_len=262_144,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        block_pattern=("mamba", "attn"),
        moe=MoEConfig(n_experts=4, experts_per_token=2, moe_every=2, moe_offset=1),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, chunk=32),
        subquadratic=True,
        max_seq_len=128,
    )
