"""command-r-35b [dense] — GQA, no-bias.

[hf:CohereForAI/c4ai-command-r-v01; unverified]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "command-r-35b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        use_bias=False,
        tie_embeddings=True,
        rope_theta=8_000_000.0,
        max_seq_len=131_072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        tie_embeddings=True,
        max_seq_len=128,
    )
