"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]).

[arXiv:2405.04517; unverified]
48L d_model=2048 4H d_ff=0 vocab=50304. No FFN (d_ff=0): the per-block
up-projections carry the capacity. Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

ARCH_ID = "xlstm-1.3b"


def _pattern(n_layers: int, slstm_every: int):
    # xLSTM[7:1]: one sLSTM block per 8, placed at the end of each group.
    return tuple(
        "slstm" if (i % slstm_every == slstm_every - 1) else "mlstm"
        for i in range(n_layers)
    )


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=_pattern(48, 8),
        xlstm=XLSTMConfig(slstm_every=8),
        subquadratic=True,
        max_seq_len=1_048_576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=256,
        block_pattern=("mlstm", "slstm"),
        xlstm=XLSTMConfig(slstm_every=2, chunk=32),
        subquadratic=True,
        max_seq_len=128,
    )
