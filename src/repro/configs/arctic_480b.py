"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic's signature is the dense residual MLP running in parallel with the MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "arctic-480b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        moe=MoEConfig(n_experts=128, experts_per_token=2, moe_every=1,
                      dense_residual=True),
        max_seq_len=4_096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        moe=MoEConfig(n_experts=4, experts_per_token=2, moe_every=1,
                      dense_residual=True),
        max_seq_len=128,
    )
