"""Config system: architecture + shape + run configs.

Every assigned architecture gets one module in ``repro.configs`` exposing
``config()`` (the exact published config) and ``smoke_config()`` (a reduced
same-family config for CPU smoke tests). The registry in ``__init__`` maps
``--arch <id>`` to these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    experts_per_token: int = 2
    moe_every: int = 1          # a layer is MoE iff (layer_idx % moe_every == moe_offset)
    moe_offset: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model/16)
    chunk: int = 256            # chunk size for the parallel scan


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8        # one sLSTM block per this many blocks (xLSTM[7:1])
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""
    kind: str = "none"          # none | clip_patches | encodec_frames
    n_embeds: int = 0           # patches / frames prepended to the token stream
    embed_dim: int = 0          # equals d_model after (stubbed) projection


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    use_bias: bool = False
    norm_eps: float = 1e-5
    # layer pattern: which block type at each layer. "attn" (full attn+mlp),
    # "mamba" (mamba mixer + mlp/moe), "mlstm", "slstm".
    block_pattern: Tuple[str, ...] = ()   # () -> all "attn"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: FrontendConfig = FrontendConfig()
    # attention impl knobs
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    subquadratic: bool = False  # True for ssm/hybrid: long_500k is runnable
    max_seq_len: int = 32_768

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        return ("attn",) * self.n_layers

    def is_moe_layer(self, idx: int) -> bool:
        m = self.moe
        if m is None or m.n_experts == 0:
            return False
        return idx % m.moe_every == m.moe_offset

    # ---- parameter counting (used for roofline MODEL_FLOPS = 6·N·D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i, kind in enumerate(self.pattern):
            if kind == "attn":
                total += d * (self.n_heads * hd)                 # q
                total += 2 * d * (self.n_kv_heads * hd)          # k, v
                total += (self.n_heads * hd) * d                 # o
                total += 2 * d                                   # norms
            elif kind == "mamba":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += d * 2 * d_in + d_in * s.d_conv
                total += d_in * (dt_rank + 2 * s.d_state) + dt_rank * d_in
                total += d_in * s.d_state + d_in                 # A_log, D
                total += d_in * d + d                            # out proj + norm
            elif kind in ("mlstm", "slstm"):
                x = self.xlstm or XLSTMConfig()
                pf = x.proj_factor_mlstm if kind == "mlstm" else x.proj_factor_slstm
                d_in = int(pf * d)
                if kind == "mlstm":
                    total += d * 2 * d_in + 3 * d_in * d_in // max(self.n_heads, 1)
                    total += d_in * d + 2 * d
                else:
                    total += 4 * d * d_in + 4 * d_in * d_in // max(self.n_heads, 1)
                    total += d_in * d + 2 * d
            # FFN / MoE (attn + mamba blocks carry an FFN in this fleet)
            if kind in ("attn", "mamba") and self.d_ff > 0:
                ffn = 3 * d * self.d_ff                          # gate, up, down
                if self.is_moe_layer(i):
                    m = self.moe
                    n_live = m.experts_per_token if active_only else m.n_experts
                    total += ffn * n_live + d * m.n_experts      # router
                    if m.dense_residual:
                        total += ffn
                else:
                    total += ffn
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in LM_SHAPES]}")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) cell is runnable. long_500k needs sub-quadratic."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: O(S^2) at 524k — skipped per brief"
    return True, ""


# ---- CNN configs (paper-faithful reproduction track) ----
@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    arch: str                   # resnet18 | mobilenetv3s
    n_classes: int = 10
    width_mult: float = 1.0
    image_size: int = 32
    stem_channels: int = 16
