"""qwen3-0.6b [dense] — qk_norm, GQA.

[hf:Qwen/Qwen3-8B; hf]
28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-0.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=3072,
        vocab_size=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        max_seq_len=40_960,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
        tie_embeddings=True,
        max_seq_len=128,
    )
