"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        moe=MoEConfig(n_experts=16, experts_per_token=2, moe_every=1),
        max_seq_len=131_072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        moe=MoEConfig(n_experts=4, experts_per_token=2, moe_every=1),
        max_seq_len=128,
    )
