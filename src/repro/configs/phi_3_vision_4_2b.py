"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
The CLIP image tower is a STUB: input_specs() provides precomputed patch
embeddings (576 patches for a 336px ViT-L/14 crop) already projected to d_model.
"""
from repro.configs.base import FrontendConfig, ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=10_000.0,
        frontend=FrontendConfig(kind="clip_patches", n_embeds=576, embed_dim=3072),
        max_seq_len=131_072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        frontend=FrontendConfig(kind="clip_patches", n_embeds=8, embed_dim=64),
        max_seq_len=128,
    )
