"""Architecture registry: ``--arch <id>`` resolution.

Ten assigned architectures + the paper's own CNNs (resnet18, mobilenetv3s).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (public re-exports)
    CNNConfig,
    FrontendConfig,
    LM_SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    get_shape,
    shape_applicable,
)

# arch id -> module under repro.configs
ARCH_MODULES: Dict[str, str] = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "arctic-480b": "arctic_480b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "musicgen-medium": "musicgen_medium",
    "xlstm-1.3b": "xlstm_1_3b",
    "stablelm-1.6b": "stablelm_1_6b",
    "command-r-35b": "command_r_35b",
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-3-8b": "granite_3_8b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
}

CNN_ARCHS = ("resnet18", "mobilenetv3s")


def list_archs() -> List[str]:
    return list(ARCH_MODULES)


def _module(arch: str):
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def get_cnn_config(arch: str) -> CNNConfig:
    from repro.configs import cnn as _cnn
    return _cnn.config(arch)

__all__ = [
    "CNNConfig", "FrontendConfig", "LM_SHAPES", "ModelConfig",
    "MoEConfig", "ShapeConfig", "SSMConfig", "XLSTMConfig",
    "get_shape", "shape_applicable", "list_archs", "get_config",
    "get_smoke_config", "get_cnn_config"]
