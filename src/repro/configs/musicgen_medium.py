"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]
48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.
The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings (conditioning frames) alongside the codec-token stream.
"""
from repro.configs.base import FrontendConfig, ModelConfig

ARCH_ID = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        frontend=FrontendConfig(kind="encodec_frames", n_embeds=256, embed_dim=1536),
        max_seq_len=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        frontend=FrontendConfig(kind="encodec_frames", n_embeds=8, embed_dim=64),
        max_seq_len=128,
    )
