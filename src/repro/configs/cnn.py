"""Paper-faithful CNN configs: ResNet-18 and MobileNetV3-Small.

These drive the faithful HQP reproduction (Tables I/II). Full ImageNet-scale
configs are impractical offline; the repro track uses 32px synthetic images
with the published block structure (depths/strides/expansions preserved,
widths scaled) — the HQP *algorithm* under test is size-agnostic.
"""
from repro.configs.base import CNNConfig


def config(arch: str) -> CNNConfig:
    if arch == "resnet18":
        return CNNConfig(name="resnet18", arch="resnet18", n_classes=10,
                         image_size=32, stem_channels=32)
    if arch == "mobilenetv3s":
        return CNNConfig(name="mobilenetv3s", arch="mobilenetv3s", n_classes=10,
                         image_size=32, stem_channels=16)
    raise KeyError(arch)
