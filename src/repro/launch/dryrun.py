import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without hardware:
``jax.jit(step, in_shardings=...).lower(**input_specs(...)).compile()`` must
succeed on the 16x16 single-pod AND 2x16x16 multi-pod meshes, and the
compiled artifact yields the roofline terms (repro.roofline.hlo_cost — the
loop-aware analyzer; XLA's cost_analysis undercounts scan bodies).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
Writes one JSON per cell to experiments/dryrun/.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import LM_SHAPES, get_config, get_shape, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.roofline import hlo_cost
from repro.roofline.hardware import TPU_V5E
from repro.sharding import rules
from repro.sharding.ctx import make_ctx
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ------------------------------------------------------------------ inputs
def input_specs(cfg, shape, quantized_kv: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> {"batch": {...}}
    prefill-> {"state": ..., "tokens": ..., ["embeds"]}
    decode -> {"state": ..., "tokens": (B, 1)}
    """
    b, s = shape.global_batch, shape.seq_len
    n_fr = cfg.frontend.n_embeds if cfg.frontend.kind != "none" else 0
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s - n_fr), jnp.int32)}
        if n_fr:
            batch["embeds"] = sds((b, n_fr, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    if shape.kind == "prefill":
        state = jax.eval_shape(
            lambda: lm.init_decode_state(cfg, b, s, _abstract_ctx(cfg, quantized_kv)))
        out = {"state": state, "tokens": sds((b, s - n_fr), jnp.int32)}
        if n_fr:
            out["embeds"] = sds((b, n_fr, cfg.d_model), jnp.bfloat16)
        return out
    # decode: one new token against a cache of seq_len
    state = jax.eval_shape(
        lambda: lm.init_decode_state(cfg, b, s, _abstract_ctx(cfg, quantized_kv)))
    return {"state": state, "tokens": sds((b, 1), jnp.int32)}


def _abstract_ctx(cfg, quantized_kv):
    import dataclasses as dc
    from repro.sharding.ctx import default_ctx
    return dc.replace(default_ctx(), quantized_kv=quantized_kv)


# ------------------------------------------------------------------ one cell
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline", ce_chunk: int = 512,
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "cell": cell_id}
    if not ok:
        rec.update(status="skipped", reason=why)
        return _finish(rec, save)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        quantized_kv = variant.startswith(("hqp", "int8kv"))
        n_chips = 512 if multi_pod else 256
        pure_dp = ("puredp" in variant
                   and shape.global_batch % n_chips == 0)
        ctx = make_ctx(mesh, batch_sharded=shape.global_batch >= 16,
                       quantized_kv=quantized_kv,
                       remat=(shape.kind == "train"),
                       moe_no_drop=(shape.kind != "train"),
                       pure_dp=pure_dp)
        params_abs = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        if variant.startswith(("hqp", "int8w")):
            # the jitted PTQ walk is traceable: eval_shape it directly
            from repro.core.quantization import quantize_lm_params
            params_abs = jax.eval_shape(quantize_lm_params, params_abs)
        p_sh = rules.param_shardings(params_abs, ctx)

        with mesh:
            if shape.kind == "train":
                n_params = cfg.param_count()
                opt_cfg = AdamWConfig(
                    state_dtype="int8" if n_params > 5e10 else "f32")
                opt_abs = jax.eval_shape(
                    lambda p: adamw_init(p, opt_cfg), params_abs)
                o_specs = rules.opt_state_specs(params_abs, opt_abs, ctx)
                o_sh = jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(mesh, s), o_specs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
                b_specs = rules.batch_specs(cfg, ctx)
                b_sh = jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(mesh, s), b_specs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
                step = make_train_step(cfg, ctx, opt_cfg)
                jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1))
                ins = input_specs(cfg, shape)
                lowered = jf.lower(params_abs, opt_abs, ins["batch"])
            else:
                ins = input_specs(cfg, shape, quantized_kv)
                s_specs = rules.decode_state_specs(cfg, ins["state"], ctx)
                s_sh = jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(mesh, s), s_specs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
                t_sh = jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(
                        ctx.batch_spec()[0], None))
                if "embeds" in ins:
                    e_sh = jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec(
                            ctx.batch_spec()[0], None, None))

                    def step(params, state, tokens, embeds):
                        return lm.decode_step(params, cfg, state, tokens,
                                              ctx, embeds)
                    jf = jax.jit(step, in_shardings=(p_sh, s_sh, t_sh, e_sh),
                                 donate_argnums=(1,))
                    lowered = jf.lower(params_abs, ins["state"],
                                       ins["tokens"], ins["embeds"])
                else:
                    def step(params, state, tokens):
                        return lm.decode_step(params, cfg, state, tokens, ctx)
                    jf = jax.jit(step, in_shardings=(p_sh, s_sh, t_sh),
                                 donate_argnums=(1,))
                    lowered = jf.lower(params_abs, ins["state"], ins["tokens"])

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        # ---------------- analyses ----------------
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["xla_cost_analysis"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
            }
        except Exception as e:  # pragma: no cover
            rec["xla_cost_analysis"] = {"error": str(e)}

        res = hlo_cost.analyze(compiled.as_text())
        chips = int(np.prod(list(mesh.shape.values())))
        chip = TPU_V5E
        bf16_flops = res.flops - res.int8_dot_flops
        t_comp = (bf16_flops / chip.peak_bf16
                  + res.int8_dot_flops / chip.peak_int8)
        t_mem = res.bytes / chip.hbm_bw
        t_coll = res.collective_bytes / chip.ici_bw
        terms = {"t_compute": t_comp, "t_memory": t_mem,
                 "t_collective": t_coll}
        dominant = max(terms, key=terms.get)
        # useful-model-flops ratio
        n_active = cfg.param_count(active_only=True)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        factor = 6 if shape.kind == "train" else 2
        model_flops = factor * n_active * tokens
        hlo_total = res.flops * chips
        rec["roofline"] = {
            "chips": chips,
            "hlo_flops_per_device": res.flops,
            "hlo_int8_flops_per_device": res.int8_dot_flops,
            "hlo_bytes_per_device": res.bytes,
            "collective_bytes_per_device": res.collective_bytes,
            "collective_breakdown": res.coll_bytes,
            "collective_counts": res.coll_counts,
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "step_time_lower_bound_s": max(terms.values()),
            "model_flops": model_flops,
            "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0,
            "roofline_fraction": (
                t_comp / max(max(terms.values()), 1e-30)
                * (model_flops / hlo_total) if hlo_total else 0.0),
        }
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return _finish(rec, save)


def _finish(rec: dict, save: bool) -> dict:
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        path = OUT_DIR / (rec["cell"].replace("/", "_") + ".json")
        path.write_text(json.dumps(rec, indent=1, default=str))
    status = rec.get("status")
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dom={r['dominant']} comp={r['t_compute']:.3e}s "
                 f"mem={r['t_memory']:.3e}s coll={r['t_collective']:.3e}s")
    elif status == "error":
        extra = " " + rec.get("error", "")[:200]
    print(f"[dryrun] {rec['cell']}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    archs = configs.list_archs() if args.arch == "all" else [args.arch]
    shapes = [s.name for s in LM_SHAPES] if args.shape == "all" else [args.shape]
    for arch in archs:
        for shape in shapes:
            mesh_name = "2x16x16" if args.multi_pod else "16x16"
            cell = f"{arch}__{shape}__{mesh_name}__{args.variant}"
            path = OUT_DIR / (cell.replace("/", "_") + ".json")
            if args.skip_existing and path.exists():
                rec = json.loads(path.read_text())
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {cell}: cached ({rec['status']})",
                          flush=True)
                    continue
            run_cell(arch, shape, args.multi_pod, args.variant)


if __name__ == "__main__":
    main()
