"""Sharded checkpointing with atomic commit + auto-resume (fault tolerance).

Layout:
  <dir>/step_000123/
      meta.json            # step, config hash, mesh shape, data-pipeline state
      arrays.npz           # flattened pytree leaves (keyed by path)
      .COMMITTED           # written last — a checkpoint without it is torn
                           # (node died mid-write) and is ignored on restore

Restore is *resharding*: arrays are loaded host-side and device_put with the
CURRENT mesh's shardings, so a checkpoint taken on 512 chips restores onto a
healthy 256-chip mesh (elastic downscale) and vice versa — the launcher's
preemption story (launch/elastic.py) relies on this.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import path_str as _path_key

COMMIT_MARKER = ".COMMITTED"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    """bfloat16 has no numpy-native representation for savez: store as a
    uint16 view under a tagged key and re-view on restore."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_key(path)
        if leaf.dtype == jnp.bfloat16:
            flat["__bf16__" + key] = np.asarray(leaf).view(np.uint16)
        else:
            flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         extra_meta: Optional[dict] = None) -> str:
    """Atomic: write into tmp dir, fsync, rename, then commit-mark."""
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:09d}"
    tmp = base / f".tmp_step_{step:09d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    meta = {"step": step, "time": time.time(),
            "n_arrays": len(flat), **(extra_meta or {})}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (final / COMMIT_MARKER).touch()
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for d in base.iterdir():
        if d.name.startswith("step_") and (d / COMMIT_MARKER).exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``tree_like``; device_put with
    ``shardings`` when given (elastic re-mesh path)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    if not (d / COMMIT_MARKER).exists():
        raise FileNotFoundError(f"checkpoint {d} is not committed (torn write)")
    data = np.load(d / "arrays.npz")
    meta = json.loads((d / "meta.json").read_text())
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    sh_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(paths))
    for (path, like), sh in zip(paths, sh_flat):
        key = _path_key(path)
        if "__bf16__" + key in data:
            import ml_dtypes
            arr = data["__bf16__" + key].view(ml_dtypes.bfloat16)
        else:
            arr = data[key]
        arr = jnp.asarray(arr, dtype=like.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


# ------------------------------------------------------------------ artifact
ARTIFACT_MANIFEST = "manifest.json"
ARTIFACT_ARRAYS = "arrays.npz"


def save_artifact(art_dir: str, artifact) -> str:
    """Persist an ``HQPArtifact`` self-describingly (atomic commit).

    Layout: ``manifest.json`` holds the compression manifest *and* the pytree
    structure spec; ``arrays.npz`` the flat leaves. Reload needs no template
    tree — the artifact is the deployment hand-off format (DESIGN.md
    §Compression-artifact)."""
    from repro.compress.artifact import tree_to_spec
    base = pathlib.Path(art_dir)
    base.parent.mkdir(parents=True, exist_ok=True)
    tmp = base.parent / f".tmp_{base.name}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays: list = []
    spec = tree_to_spec(artifact.params, arrays)
    np.savez(tmp / ARTIFACT_ARRAYS,
             **{f"a{i}": a for i, a in enumerate(arrays)})
    (tmp / ARTIFACT_MANIFEST).write_text(json.dumps(
        {"manifest": artifact.manifest.asdict(), "tree": spec,
         "n_arrays": len(arrays), "time": time.time()}))
    if base.exists():
        shutil.rmtree(base)
    tmp.rename(base)
    (base / COMMIT_MARKER).touch()
    return str(base)


def load_artifact(art_dir: str):
    """Inverse of ``save_artifact`` -> ``HQPArtifact``."""
    from repro.compress.artifact import HQPArtifact, HQPManifest, spec_to_tree
    base = pathlib.Path(art_dir)
    if not base.exists():
        raise FileNotFoundError(f"no artifact at {base}")
    if not (base / COMMIT_MARKER).exists():
        raise FileNotFoundError(f"artifact {base} is not committed (torn write)")
    meta = json.loads((base / ARTIFACT_MANIFEST).read_text())
    data = np.load(base / ARTIFACT_ARRAYS)
    arrays = [data[f"a{i}"] for i in range(meta["n_arrays"])]
    params = spec_to_tree(meta["tree"], arrays)
    return HQPArtifact(params=params,
                       manifest=HQPManifest.fromdict(meta["manifest"]))


def prune_old(ckpt_dir: str, keep: int = 3):
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return
    steps = sorted(int(d.name.split("_")[1]) for d in base.iterdir()
                   if d.name.startswith("step_")
                   and (d / COMMIT_MARKER).exists())
    for s in steps[:-keep]:
        shutil.rmtree(base / f"step_{s:09d}", ignore_errors=True)
