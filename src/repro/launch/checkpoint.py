"""Sharded checkpointing with atomic commit + auto-resume (fault tolerance).

Layout:
  <dir>/step_000123/
      meta.json            # step, config hash, mesh shape, data-pipeline state
      arrays.npz           # flattened pytree leaves (keyed by path)
      .COMMITTED           # written last — a checkpoint without it is torn
                           # (node died mid-write) and is ignored on restore

Restore is *resharding*: arrays are loaded host-side and device_put with the
CURRENT mesh's shardings, so a checkpoint taken on 512 chips restores onto a
healthy 256-chip mesh (elastic downscale) and vice versa — the launcher's
preemption story (launch/elastic.py) relies on this.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

COMMIT_MARKER = ".COMMITTED"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    """bfloat16 has no numpy-native representation for savez: store as a
    uint16 view under a tagged key and re-view on restore."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if leaf.dtype == jnp.bfloat16:
            flat["__bf16__" + key] = np.asarray(leaf).view(np.uint16)
        else:
            flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         extra_meta: Optional[dict] = None) -> str:
    """Atomic: write into tmp dir, fsync, rename, then commit-mark."""
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:09d}"
    tmp = base / f".tmp_step_{step:09d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    meta = {"step": step, "time": time.time(),
            "n_arrays": len(flat), **(extra_meta or {})}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (final / COMMIT_MARKER).touch()
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for d in base.iterdir():
        if d.name.startswith("step_") and (d / COMMIT_MARKER).exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``tree_like``; device_put with
    ``shardings`` when given (elastic re-mesh path)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    if not (d / COMMIT_MARKER).exists():
        raise FileNotFoundError(f"checkpoint {d} is not committed (torn write)")
    data = np.load(d / "arrays.npz")
    meta = json.loads((d / "meta.json").read_text())
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    sh_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(paths))
    for (path, like), sh in zip(paths, sh_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if "__bf16__" + key in data:
            import ml_dtypes
            arr = data["__bf16__" + key].view(ml_dtypes.bfloat16)
        else:
            arr = data[key]
        arr = jnp.asarray(arr, dtype=like.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def prune_old(ckpt_dir: str, keep: int = 3):
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return
    steps = sorted(int(d.name.split("_")[1]) for d in base.iterdir()
                   if d.name.startswith("step_")
                   and (d / COMMIT_MARKER).exists())
    for s in steps[:-keep]:
        shutil.rmtree(base / f"step_{s:09d}", ignore_errors=True)
