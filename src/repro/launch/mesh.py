"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))
