"""Serving launcher: HQP artifacts through the batched or continuous-batching path.

Deliverable (b) inference driver: acquires a model (fresh init, full HQP
pipeline, or a saved artifact — loading NEVER re-runs sensitivity /
calibration), prints the artifact manifest, then serves synthetic requests.

Two serving paths:

  default      one batch, lockstep prefill + decode (the PR-1 smoke loop)
  --engine     continuous batching (``repro.serving.Engine``): slot-based
               admission/eviction, chunked prefill interleaved with batched
               decode, per-request latency stats; replays a request trace
               (``--trace``, JSONL) or a synthetic staggered-arrival load.
               With ``--verify`` (default under ``--smoke``) every engine
               output is checked token-identical against serial decode.

``--temperature/--top-k/--seed`` drive seeded sampling on every decode
surface (default greedy). ``--spec-k N`` (engine mode, with ``--hqp`` or
``--load-artifact``) turns on self-speculative serving: the HQP artifact
drafts N tokens per cycle, the bf16 parent verifies — greedy output stays
bit-identical to serial bf16 decode (``--verify`` checks exactly that).

  python -m repro.launch.serve --arch qwen3-0.6b --smoke --hqp --tokens 32
  python -m repro.launch.serve --arch qwen3-0.6b --smoke --engine
  python -m repro.launch.serve --arch qwen3-0.6b --smoke --engine --hqp \\
      --spec-k 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.sharding.ctx import make_ctx
from repro.train.train_step import make_eval_step, make_serve_step


def _calib_batch(cfg, batch: int, seq: int, seed: int = 17) -> dict:
    rng = np.random.RandomState(seed)
    b = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if cfg.frontend.kind != "none":
        b["embeds"] = jnp.zeros((batch, cfg.frontend.n_embeds, cfg.d_model),
                                jnp.bfloat16)
    return b


def build_artifact(params, cfg, ctx, prune_steps: int, log=print):
    """HQP artifact for serving: one-batch Fisher pass + next-token-accuracy
    eval drive the conditional prune; PTQ is the jitted on-device path."""
    from repro.core.pipeline import HQPConfig
    from repro.core.sensitivity import fisher_diag
    from repro.compress import compress

    batch = _calib_batch(cfg, batch=2, seq=32)
    grad = jax.jit(jax.grad(
        lambda p, b: lm.loss_fn(p, cfg, b, ctx, with_aux=False)[0]))
    sq, _ = fisher_diag(grad, params, [batch])
    eval_step = jax.jit(make_eval_step(cfg, ctx))
    eval_fn = lambda p: float(eval_step(p, batch))
    hqp = HQPConfig(weight_granularity="channel", step_frac=0.05,
                    max_steps=prune_steps)
    return compress(params, cfg, sq_grads=sq, eval_fn=eval_fn, hqp=hqp,
                    log=log)


def acquire_params(args, cfg, ctx, log=print):
    """Resolve the model to serve. Exactly one of three paths runs:

    load-artifact  deserialize; NO gradients, NO Fisher pass, NO eval — a
                   saved artifact already paid for its calibration
    --hqp          init + full pipeline (optionally --save-artifact)
    plain          fresh bf16 init

    Returns ``(params, manifest, parent)``: ``manifest`` is the HQP
    manifest when ``params`` is an artifact (else None); ``parent`` is the
    full-precision pytree the artifact was compressed from when it exists
    in-process (the --hqp path) — the speculative verifier.
    """
    if args.load_artifact:
        from repro.launch.checkpoint import load_artifact
        art = load_artifact(args.load_artifact)
        if art.manifest.arch != cfg.name:
            raise SystemExit(
                f"artifact was built for {art.manifest.arch!r}, requested "
                f"config is {cfg.name!r} — pass the matching --arch/--smoke")
        log(art.manifest.summary())
        return art.params, art.manifest, None
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.hqp:
        art = build_artifact(params, cfg, ctx, args.prune_steps, log=log)
        log(art.manifest.summary())
        if args.save_artifact:
            from repro.launch.checkpoint import save_artifact
            log(f"[serve] artifact saved to "
                f"{save_artifact(args.save_artifact, art)}")
        return art.params, art.manifest, params
    return params, None, None


# ------------------------------------------------------------------ engine
def _attach_tracer(eng, trace_dir):
    """Hang a span recorder off the engine when ``--trace-dir`` asks for
    one. The recorder is a passive sink — the engine stamps every event
    with its own injectable clock, so attaching it costs nothing until
    events actually flow."""
    if not trace_dir:
        return None
    from repro import telemetry
    tracer = telemetry.SpanRecorder()
    eng.tracer = tracer
    return tracer


def _write_tracer(tracer, trace_dir, log):
    if tracer is None:
        return
    from repro import telemetry
    trace_path, jsonl_path = telemetry.write_trace(trace_dir, tracer)
    log(f"[trace] wrote {trace_path} (Perfetto/chrome://tracing) and "
        f"{jsonl_path}")


def _start_profiler(profile_dir, log) -> bool:
    """``--profile-dir``: wrap the engine run in ``jax.profiler.trace``.
    Gated — some backends ship without profiler support, and a missing
    profiler must degrade to a log line, not kill the serve."""
    if not profile_dir:
        return False
    try:
        jax.profiler.start_trace(profile_dir)
        return True
    except Exception as e:                          # noqa: BLE001
        log(f"[profile] jax.profiler unavailable ({e}); continuing without")
        return False


def _stop_profiler(started: bool, profile_dir, log) -> None:
    if not started:
        return
    try:
        jax.profiler.stop_trace()
        log(f"[profile] device profile written under {profile_dir}")
    except Exception as e:                          # noqa: BLE001
        log(f"[profile] stop_trace failed ({e})")


def load_trace(path: str, cfg, seed: int = 0):
    """JSONL request trace: one object per line with ``arrival_s`` (float,
    offset from replay start) and either ``prompt`` (token ids) or
    ``prompt_len`` (synthesized from ``seed``); optional ``max_new_tokens``
    (default 16) and ``eos_id``."""
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    reqs, arrivals = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "prompt" in d:
                prompt = d["prompt"]
                if not prompt:
                    raise ValueError(f"trace line has an empty prompt: {d}")
            elif "prompt_len" in d:
                prompt = rng.randint(
                    0, cfg.vocab_size, int(d["prompt_len"])).tolist()
            else:
                raise ValueError(
                    f"trace line needs 'prompt' or 'prompt_len': {d}")
            reqs.append(Request(prompt=prompt,
                                max_new_tokens=int(d.get("max_new_tokens", 16)),
                                eos_id=d.get("eos_id")))
            arrivals.append(float(d.get("arrival_s", 0.0)))
    return reqs, arrivals


def synth_requests(cfg, n: int, prompt_len: int, max_new_tokens: int,
                   gap_s: float = 0.02, seed: int = 0):
    """Staggered synthetic load: varying prompt lengths so chunked prefill
    genuinely interleaves with decode of earlier requests."""
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    lens = [max(4, prompt_len + (i * 7) % 11 - 5) for i in range(n)]
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, L).tolist(),
                    max_new_tokens=max_new_tokens) for L in lens]
    return reqs, [i * gap_s for i in range(n)]


def build_engine(params, cfg, ctx, args, sampling=None, draft=None):
    """One Engine from the serve flags — shared by trace replay
    (``run_engine``) and the HTTP front door (``serve_http``), so both
    paths serve the exact same configuration."""
    from repro.serving import Engine, SchedulerConfig
    spec_kw = {}
    if draft is not None:
        draft_params, draft_ctx, manifest = draft
        spec_kw = dict(draft_params=draft_params, draft_ctx=draft_ctx,
                       spec_k=args.spec_k, draft_manifest=manifest)
    return Engine(params, cfg, ctx=ctx, n_slots=args.engine_slots,
                  max_seq=args.max_seq,
                  sched=SchedulerConfig(prefill_chunk=args.prefill_chunk,
                                        decode_steps=args.decode_steps),
                  sampling=sampling, page_size=args.page_size or None,
                  total_pages=getattr(args, "total_pages", 0) or None,
                  prefix_cache=not args.no_prefix_cache, **spec_kw)


def serve_http(params, cfg, ctx, args, log=print, sampling=None, draft=None):
    """``serve --http``: the engine behind the asyncio SSE front door.
    Blocks until SIGTERM/SIGINT, then drains in-flight slots (DESIGN §13).
    A warmup request pays the jit-compile cost before the listener opens so
    the first client's TTFT measures serving, not tracing."""
    from repro.serving import Request
    from repro.serving.service import Service, ServiceConfig, run_http
    eng = build_engine(params, cfg, ctx, args, sampling=sampling, draft=draft)
    t0 = time.monotonic()
    eng.run([Request(prompt=[3, 1, 4, 1, 5, 9], max_new_tokens=2)])
    for k in eng.stats:
        eng.stats[k] = 0
    log(f"[http] warmup compile: {time.monotonic() - t0:.1f}s")
    admission = None
    if not args.no_feasibility:
        from repro.serving import AdmissionController
        admission = AdmissionController()
    svc = Service(eng, ServiceConfig(queue_depth=args.queue_depth,
                                     default_deadline_s=args.deadline_s),
                  admission=admission)
    # attach AFTER the warmup request so the trace starts at the first
    # client-visible submit
    tracer = _attach_tracer(eng, args.trace_dir)
    prof = _start_profiler(args.profile_dir, log)
    run_http(svc, host=args.host, port=args.port, log=log,
             watchdog_s=args.watchdog_s or None)
    _stop_profiler(prof, args.profile_dir, log)
    _write_tracer(tracer, args.trace_dir, log)
    return svc


def run_engine(params, cfg, ctx, args, log=print, sampling=None, draft=None):
    """``draft`` = (draft_params, draft_ctx, manifest) switches the engine
    into speculative mode: ``params`` is then the bf16 VERIFIER and the
    drafter is the HQP artifact. ``--verify`` still compares against serial
    decode of ``params`` — in speculative greedy mode that is exactly the
    bit-identity guarantee (the artifact only ever proposes)."""
    from repro.serving import serial_decode, summarize_results
    if args.trace:
        reqs, arrivals = load_trace(args.trace, cfg)
        log(f"[engine] replaying trace {args.trace}: {len(reqs)} requests")
    else:
        n = max(3, args.batch)
        reqs, arrivals = synth_requests(cfg, n, args.prompt_len, args.tokens)
        log(f"[engine] synthetic load: {n} staggered requests")
    if not reqs:
        raise SystemExit("[engine] trace contains no requests")
    need = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    if need > args.max_seq:
        raise SystemExit(f"trace needs max-seq >= {need}, got {args.max_seq}")

    eng = build_engine(params, cfg, ctx, args, sampling=sampling, draft=draft)
    tracer = _attach_tracer(eng, args.trace_dir)
    prof = _start_profiler(args.profile_dir, log)
    t0 = time.monotonic()
    results = eng.run(reqs, arrivals_s=arrivals)
    wall = time.monotonic() - t0
    _stop_profiler(prof, args.profile_dir, log)
    _write_tracer(tracer, args.trace_dir, log)

    stats = {
        **summarize_results(results, wall),
        "n_slots": args.engine_slots,
        "prefill_chunk": args.prefill_chunk,
        **eng.stats,
    }
    accept = (eng.stats["accepted_tokens"] /
              max(eng.stats["drafted_tokens"], 1))
    stats["acceptance_rate"] = accept
    log(f"[engine] {stats['n_requests']} requests in {wall*1000:.0f}ms: "
        f"{stats['tokens_per_s']:.1f} tok/s, "
        f"latency p50/p95 {stats['latency_p50_ms']:.0f}/"
        f"{stats['latency_p95_ms']:.0f}ms, "
        f"ttft p50/p95 {stats['ttft_p50_ms']:.0f}/"
        f"{stats['ttft_p95_ms']:.0f}ms "
        f"(ticks: {eng.stats['prefill_ticks']}p/{eng.stats['decode_ticks']}d, "
        f"{eng.stats['device_steps']} device decode steps / "
        f"{eng.stats['host_syncs']} host syncs"
        + (f", spec acceptance {accept:.2f}" if draft is not None else "")
        + (f", {eng.stats['prefix_hits']} prefix hits / "
           f"{eng.stats['pages_peak']} pages peak" if args.page_size else "")
        + ")")

    verify = args.verify if args.verify is not None else args.smoke
    if verify and draft is not None and sampling is not None \
            and not sampling.is_greedy:
        log("[engine] verify skipped: speculative sampling matches the "
            "verifier's DISTRIBUTION, not its token sequence (greedy "
            "speculative mode is token-identical and verifiable)")
        verify = False
    if verify:
        bad = []
        for i, res in sorted(results.items()):
            req = reqs[i]
            ref = serial_decode(params, cfg, req.prompt, req.max_new_tokens,
                                ctx=ctx, max_seq=args.max_seq,
                                eos_id=req.eos_id, sampling=sampling)
            if res.tokens != ref:
                bad.append(i)
        if bad:
            raise SystemExit(f"[engine] VERIFY FAILED: requests {bad} differ "
                             f"from serial single-request decode")
        log(f"[engine] verify: all {len(results)} outputs token-identical "
            f"to serial decode")
    return results, stats


# -------------------------------------------------------------------- main
def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--hqp", action="store_true",
                    help="full HQP artifact: prune -> INT8 weights + INT8 KV")
    ap.add_argument("--prune-steps", type=int, default=3,
                    help="conditional-prune δ-steps for the serving artifact")
    ap.add_argument("--save-artifact", default=None,
                    help="directory to persist the HQP artifact (atomic)")
    ap.add_argument("--load-artifact", default=None,
                    help="serve a previously saved HQP artifact (skips all "
                         "sensitivity/calibration work)")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine instead of the "
                         "single-batch lockstep loop")
    ap.add_argument("--engine-slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=4,
                    help="batched decode steps per device dispatch (the "
                         "jitted lax.scan length; 1 = sync every token)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft length: the HQP artifact drafts "
                         "K tokens per cycle, the bf16 parent verifies "
                         "(engine mode, requires --hqp or --load-artifact; "
                         "0 = off)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling cutoff (0 = full vocabulary)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed; same seed => same tokens, engine "
                         "and serial alike")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: arena page size in tokens (engine "
                         "mode; 0 = contiguous per-slot pool). Outputs are "
                         "token-identical at every page size")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable hash-keyed shared-prefix page reuse "
                         "(paged mode only)")
    ap.add_argument("--total-pages", type=int, default=0,
                    help="paged KV arena size in pages (0 = full "
                         "provisioning, 1 + slots*ceil(max_seq/page_size)); "
                         "undersizing forces arena-exhaustion behavior — "
                         "chaos testing / memory-capped deployments")
    ap.add_argument("--trace", default=None,
                    help="JSONL request trace to replay (engine mode)")
    ap.add_argument("--trace-dir", default=None,
                    help="write per-request span traces here after the run: "
                         "trace.json (Chrome trace-event JSON, loadable in "
                         "Perfetto or chrome://tracing) plus spans.jsonl "
                         "(engine mode, trace replay or --http)")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the engine run in jax.profiler.trace and "
                         "write the device profile here (engine mode; "
                         "degrades to a log line if the backend has no "
                         "profiler)")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP with SSE token streaming instead "
                         "of replaying a trace (implies --engine; blocks "
                         "until SIGTERM, then drains in-flight requests)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="HTTP bind address (--http)")
    ap.add_argument("--port", type=int, default=8080,
                    help="HTTP bind port; 0 picks a free port (--http)")
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="admission queue bound beyond the slots: more than "
                         "slots+depth requests in flight => shed with 429 "
                         "(--http)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline in seconds; expired "
                         "requests are evicted mid-flight and stream "
                         "finish_reason=deadline (--http; per-request "
                         "'deadline_s' in the POST body overrides)")
    ap.add_argument("--no-feasibility", action="store_true",
                    help="disable deadline-feasibility admission (the EWMA "
                         "throughput predictor that sheds deadlined "
                         "requests it cannot serve in time, DESIGN.md §14); "
                         "the static slots+queue-depth cap always applies")
    ap.add_argument("--watchdog-s", type=float, default=300.0,
                    help="pump watchdog: if the engine thread makes no "
                         "progress for this long the server exits with "
                         "status 2 instead of hanging (0 disables; --http)")
    ap.add_argument("--verify", action="store_true", default=None,
                    help="check engine outputs == serial decode "
                         "(default: on under --smoke)")
    args = ap.parse_args(argv)

    if args.http:
        args.engine = True           # the front door is an engine transport
        if args.trace:
            ap.error("--http serves live requests; --trace replays a file — "
                     "pick one")
    if args.save_artifact and not args.hqp:
        ap.error("--save-artifact requires --hqp (nothing to save otherwise)")
    if args.save_artifact and args.load_artifact:
        ap.error("--save-artifact with --load-artifact would just copy the "
                 "artifact; use the filesystem for that")
    if args.page_size and not args.engine:
        ap.error("--page-size needs --engine (the lockstep loop has no "
                 "slot pool to page)")
    if (args.trace_dir or args.profile_dir) and not args.engine:
        ap.error("--trace-dir/--profile-dir need --engine (spans and phase "
                 "attribution are engine-step concepts)")
    use_hqp = args.hqp or args.load_artifact is not None
    if args.spec_k:
        if not args.engine:
            ap.error("--spec-k needs --engine (speculation is an engine "
                     "decode mode)")
        if not use_hqp:
            ap.error("--spec-k needs a drafter: pass --hqp (build one) or "
                     "--load-artifact")

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_host_mesh()
    ctx = make_ctx(mesh, batch_sharded=False, quantized_kv=use_hqp)

    params, manifest, parent = acquire_params(args, cfg, ctx)
    from repro.serving import SamplingConfig
    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=args.top_k, seed=args.seed)

    if args.engine:
        draft = None
        if args.spec_k:
            if parent is None:
                # --load-artifact path: the artifact's parent weights are
                # not in the checkpoint; re-init the deterministic seed-0
                # parent (manifest arch-hash still guards arch mismatch).
                # Loud on purpose: if the artifact came from ANY other
                # weights (different seed, trained checkpoint), this
                # verifier is an unrelated model — output stays
                # verifier-faithful but acceptance collapses.
                print("[serve] WARNING: --spec-k with --load-artifact "
                      "re-initializes the seed-0 bf16 parent as the "
                      "verifier; if the artifact was built from other "
                      "weights, expect near-zero acceptance (pass --hqp "
                      "to build drafter and verifier from the same "
                      "params)")
                parent = lm.init_params(jax.random.PRNGKey(0), cfg)
            draft_ctx = ctx                  # quantized_kv=True: INT8 KV
            ctx = dataclasses.replace(ctx, quantized_kv=False)  # verifier
            draft = (params, draft_ctx, manifest)
            params = parent
        with mesh:
            if args.http:
                svc = serve_http(params, cfg, ctx, args, sampling=sampling,
                                 draft=draft)
                return svc.stats
            _, stats = run_engine(params, cfg, ctx, args, sampling=sampling,
                                  draft=draft)
        return stats

    serve_step = jax.jit(make_serve_step(cfg, ctx), donate_argnums=(1,))

    with mesh:
        state = lm.init_decode_state(cfg, args.batch, args.max_seq, ctx,
                                     params=params if use_hqp else None)
        rng = np.random.RandomState(0)
        prompts = jnp.asarray(rng.randint(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
        t0 = time.time()
        if cfg.frontend.kind != "none":
            embeds = jnp.zeros((args.batch, cfg.frontend.n_embeds,
                                cfg.d_model), jnp.bfloat16)
            logits, state = lm.decode_step(params, cfg, state, prompts, ctx,
                                           embeds)
        else:
            logits, state = serve_step(params, state, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        # sampling on the lockstep path shares the engine's key rule (seed x
        # absolute position); greedy stays on the original argmax
        from repro.serving import sampling as smp
        base = smp.base_key(sampling)
        pick = jax.jit(lambda lg, pos: smp.sample_batch(
            lg[:, -1], sampling, base,
            jnp.full((lg.shape[0],), pos, jnp.int32))[:, None])
        pos = args.prompt_len
        tok = pick(logits, pos)
        outputs = [tok]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, state = serve_step(params, state, tok)
            pos += 1
            tok = pick(logits, pos)
            outputs.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = jnp.concatenate(outputs, axis=1)
    tps = args.batch * (args.tokens - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1000:.1f}ms; decode {args.tokens-1} steps: "
          f"{tps:.1f} tok/s")
    print(f"[serve] sample continuation (req 0): {np.asarray(out[0])[:16]}")
    return out


if __name__ == "__main__":
    main()
