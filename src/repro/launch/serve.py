"""Serving launcher: batched prefill + decode with the HQP-compressed model.

Deliverable (b) inference driver: loads (or initializes) a model, optionally
applies the full HQP pipeline (sensitivity prune -> INT8 PTQ -> INT8 KV
cache), then serves a batch of synthetic requests through cache-filling
prefill and token-by-token decode, reporting tokens/s and the compression
metrics next to each other — the LM analogue of the paper's Tables I/II.

  python -m repro.launch.serve --arch qwen3-0.6b --smoke --hqp --tokens 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.sharding.ctx import make_ctx
from repro.train.train_step import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--hqp", action="store_true",
                    help="INT8 weights + INT8 KV cache")
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_host_mesh()
    ctx = make_ctx(mesh, batch_sharded=False, quantized_kv=args.hqp)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    from repro.core.pruning import param_bytes
    size0 = param_bytes(params)
    if args.hqp:
        from repro.core.quantization import quantize_lm_params
        params = quantize_lm_params(params)
        print(f"[serve] HQP INT8: {size0/1e6:.1f}MB -> "
              f"{param_bytes(params)/1e6:.1f}MB")

    serve_step = jax.jit(make_serve_step(cfg, ctx), donate_argnums=(1,))

    with mesh:
        state = lm.init_decode_state(cfg, args.batch, args.max_seq, ctx)
        rng = np.random.RandomState(0)
        prompts = jnp.asarray(rng.randint(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
        t0 = time.time()
        if cfg.frontend.kind != "none":
            embeds = jnp.zeros((args.batch, cfg.frontend.n_embeds,
                                cfg.d_model), jnp.bfloat16)
            logits, state = lm.decode_step(params, cfg, state, prompts, ctx,
                                           embeds)
        else:
            logits, state = serve_step(params, state, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outputs = [tok]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, state = serve_step(params, state, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outputs.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = jnp.concatenate(outputs, axis=1)
    tps = args.batch * (args.tokens - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1000:.1f}ms; decode {args.tokens-1} steps: "
          f"{tps:.1f} tok/s")
    print(f"[serve] sample continuation (req 0): {np.asarray(out[0])[:16]}")
    return out


if __name__ == "__main__":
    main()
