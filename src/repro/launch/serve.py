"""Serving launcher: batched prefill + decode with the HQP-compressed model.

Deliverable (b) inference driver: loads (or initializes) a model, optionally
runs the full HQP pipeline through the typed artifact entrypoint
(``repro.compress.compress``: Fisher sensitivity -> conditional prune ->
compaction -> on-device INT8 PTQ -> INT8 KV cache), prints the artifact
manifest (bytes, quantized fraction, per-family θ), then serves a batch of
synthetic requests through cache-filling prefill and token-by-token decode,
reporting tokens/s next to the compression metrics — the LM analogue of the
paper's Tables I/II.

  python -m repro.launch.serve --arch qwen3-0.6b --smoke --hqp --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.sharding.ctx import make_ctx
from repro.train.train_step import make_eval_step, make_serve_step


def _calib_batch(cfg, batch: int, seq: int, seed: int = 17) -> dict:
    rng = np.random.RandomState(seed)
    b = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if cfg.frontend.kind != "none":
        b["embeds"] = jnp.zeros((batch, cfg.frontend.n_embeds, cfg.d_model),
                                jnp.bfloat16)
    return b


def build_artifact(params, cfg, ctx, prune_steps: int, log=print):
    """HQP artifact for serving: one-batch Fisher pass + next-token-accuracy
    eval drive the conditional prune; PTQ is the jitted on-device path."""
    from repro.core.pipeline import HQPConfig
    from repro.core.sensitivity import fisher_diag
    from repro.compress import compress

    batch = _calib_batch(cfg, batch=2, seq=32)
    grad = jax.jit(jax.grad(
        lambda p, b: lm.loss_fn(p, cfg, b, ctx, with_aux=False)[0]))
    sq, _ = fisher_diag(grad, params, [batch])
    eval_step = jax.jit(make_eval_step(cfg, ctx))
    eval_fn = lambda p: float(eval_step(p, batch))
    hqp = HQPConfig(weight_granularity="channel", step_frac=0.05,
                    max_steps=prune_steps)
    return compress(params, cfg, sq_grads=sq, eval_fn=eval_fn, hqp=hqp,
                    log=log)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--hqp", action="store_true",
                    help="full HQP artifact: prune -> INT8 weights + INT8 KV")
    ap.add_argument("--prune-steps", type=int, default=3,
                    help="conditional-prune δ-steps for the serving artifact")
    ap.add_argument("--save-artifact", default=None,
                    help="directory to persist the HQP artifact (atomic)")
    ap.add_argument("--load-artifact", default=None,
                    help="serve a previously saved HQP artifact")
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    if args.save_artifact and not args.hqp:
        ap.error("--save-artifact requires --hqp (nothing to save otherwise)")

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_host_mesh()
    use_hqp = args.hqp or args.load_artifact is not None
    ctx = make_ctx(mesh, batch_sharded=False, quantized_kv=use_hqp)

    if args.load_artifact:
        from repro.launch.checkpoint import load_artifact
        art = load_artifact(args.load_artifact)
        if art.manifest.arch != cfg.name:
            raise SystemExit(
                f"artifact was built for {art.manifest.arch!r}, requested "
                f"config is {cfg.name!r} — pass the matching --arch/--smoke")
        print(art.manifest.summary())
        params = art.params
    else:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        if args.hqp:
            art = build_artifact(params, cfg, ctx, args.prune_steps)
            print(art.manifest.summary())
            params = art.params
            if args.save_artifact:
                from repro.launch.checkpoint import save_artifact
                print(f"[serve] artifact saved to "
                      f"{save_artifact(args.save_artifact, art)}")

    serve_step = jax.jit(make_serve_step(cfg, ctx), donate_argnums=(1,))

    with mesh:
        state = lm.init_decode_state(cfg, args.batch, args.max_seq, ctx,
                                     params=params if use_hqp else None)
        rng = np.random.RandomState(0)
        prompts = jnp.asarray(rng.randint(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
        t0 = time.time()
        if cfg.frontend.kind != "none":
            embeds = jnp.zeros((args.batch, cfg.frontend.n_embeds,
                                cfg.d_model), jnp.bfloat16)
            logits, state = lm.decode_step(params, cfg, state, prompts, ctx,
                                           embeds)
        else:
            logits, state = serve_step(params, state, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outputs = [tok]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, state = serve_step(params, state, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            outputs.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = jnp.concatenate(outputs, axis=1)
    tps = args.batch * (args.tokens - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1000:.1f}ms; decode {args.tokens-1} steps: "
          f"{tps:.1f} tok/s")
    print(f"[serve] sample continuation (req 0): {np.asarray(out[0])[:16]}")
    return out


if __name__ == "__main__":
    main()
