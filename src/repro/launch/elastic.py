"""Elastic re-meshing + straggler mitigation policies (1000+ node posture).

``replan(...)`` is the core primitive: given the current device inventory
(after failures / preemptions / capacity changes) choose a new mesh shape,
re-derive shardings, and restore the latest committed checkpoint onto it —
keeping the GLOBAL batch constant by adjusting the microbatch count, so the
optimizer trajectory is unchanged across re-meshes.

Straggler mitigation at this layer is *topology-aware exclusion*: a chronic
straggler (slow HBM / thermally throttled chip) is dropped from the healthy
set and the mesh re-planned around it; within-step mitigation on real fleets
(bitwise-deterministic redundant dispatch) is out of scope for a dry-run
container and documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.launch import checkpoint as ckpt
from repro.sharding import rules
from repro.sharding.ctx import make_ctx


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    num_microbatches: int
    dropped_devices: List[int]


def choose_mesh_shape(n_devices: int, model_parallel: int,
                      global_batch: int) -> Tuple[int, int]:
    """Largest (data, model) grid fitting the healthy device count, keeping
    the model axis fixed (TP width is a property of the model, not the fleet)
    and data divisible into the global batch."""
    data = n_devices // model_parallel
    while data > 1 and (global_batch % data != 0):
        data -= 1
    if data < 1:
        raise ValueError(
            f"cannot fit model_parallel={model_parallel} in {n_devices}")
    return data, model_parallel


def replan(healthy_devices: Sequence, model_parallel: int,
           global_batch: int, target_microbatch_tokens: int,
           seq_len: int) -> ElasticPlan:
    n = len(healthy_devices)
    data, model = choose_mesh_shape(n, model_parallel, global_batch)
    per_device_batch = global_batch // data
    micro = max(1, int(np.ceil(
        per_device_batch * seq_len / max(target_microbatch_tokens, 1))))
    while global_batch % (micro) or (global_batch // data) % micro:
        micro -= 1
    return ElasticPlan((data, model), ("data", "model"), max(micro, 1), [])


def rebuild(plan: ElasticPlan, devices: Sequence, params_like,
            opt_like, ckpt_dir: str):
    """Construct the new mesh and restore the latest checkpoint onto it."""
    devs = np.array(devices[: int(np.prod(plan.mesh_shape))]).reshape(
        plan.mesh_shape)
    mesh = Mesh(devs, plan.axis_names)
    ctx = make_ctx(mesh)
    p_sh = rules.param_shardings(params_like, ctx)
    (params, opt_state), meta = ckpt.restore(
        ckpt_dir, (params_like, opt_like), shardings=(p_sh, None))
    return mesh, ctx, params, opt_state, meta


@dataclasses.dataclass
class StragglerPolicy:
    """Exclude devices whose step time is persistently above the fleet
    median by `threshold` (e.g. 1.5x) for `patience` consecutive steps."""
    threshold: float = 1.5
    patience: int = 20

    def __post_init__(self):
        self._strikes = {}

    def observe(self, step_times_by_device: dict) -> List:
        med = float(np.median(list(step_times_by_device.values())))
        to_drop = []
        for dev, t in step_times_by_device.items():
            if t > self.threshold * med:
                self._strikes[dev] = self._strikes.get(dev, 0) + 1
                if self._strikes[dev] >= self.patience:
                    to_drop.append(dev)
            else:
                self._strikes[dev] = 0
        return to_drop
