"""LM training launcher: data -> sharded train loop -> checkpoints -> resume.

End-to-end driver (deliverable (b)): trains any ``--arch`` (reduced or full
config) with the production substrate — sharded params, microbatching, int8
optimizer states for >50B models, step checkpointing with auto-resume, and a
preemption signal handler (SIGTERM triggers a final checkpoint, the restart
resumes exactly — fault-tolerance path exercised in tests/test_checkpoint.py).

CPU-smoke example (examples/train_lm.py wraps this):
  python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import SyntheticTokens
from repro.launch import checkpoint as ckpt
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.sharding import rules
from repro.sharding.ctx import make_ctx
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_eval_step, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--state-dtype", default="f32", choices=["f32", "int8"])
    ap.add_argument("--eval-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_host_mesh()
    ctx = make_ctx(mesh, batch_sharded=args.batch >= mesh.shape["data"],
                   moe_no_drop=False)       # training: capacity_factor drops
    opt_cfg = AdamWConfig(lr=args.lr, state_dtype=args.state_dtype)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params, opt_cfg)
    p_sh = rules.param_shardings(params, ctx)
    params = jax.device_put(params, p_sh)

    data = SyntheticTokens(cfg.vocab_size, args.seq + 1, 4096, seed=0)
    val = SyntheticTokens(cfg.vocab_size, args.seq + 1, 512, seed=7)

    step_fn = jax.jit(make_train_step(cfg, ctx, opt_cfg, args.microbatches),
                      donate_argnums=(0, 1))
    eval_fn = jax.jit(make_eval_step(cfg, ctx))

    start_step = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), meta = ckpt.restore(
                args.ckpt_dir, (params, opt_state))
            start_step = meta["step"]
            print(f"[train] resumed from step {start_step}")

    stop = {"flag": False}

    def _preempt(signum, frame):
        print("[train] preemption signal — checkpointing and exiting")
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _preempt)

    it = data.batches(args.batch, seed=start_step, epochs=10_000)
    t0 = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            batch = {"tokens": jnp.asarray(next(it)["tokens"])}
            if cfg.frontend.kind != "none":
                batch["embeds"] = jnp.zeros(
                    (args.batch, cfg.frontend.n_embeds, cfg.d_model),
                    jnp.bfloat16)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0):.1f}s)")
            if args.eval_every and (step + 1) % args.eval_every == 0:
                vb = next(val.batches(args.batch))
                evb = {"tokens": jnp.asarray(vb["tokens"])}
                if cfg.frontend.kind != "none":
                    evb["embeds"] = batch["embeds"]
                acc = float(eval_fn(params, evb))
                print(f"[train] step {step} next-token-acc={acc:.4f}")
            if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0
                                  or stop["flag"]):
                path = ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                                 {"arch": args.arch})
                ckpt.prune_old(args.ckpt_dir)
                print(f"[train] checkpointed -> {path}")
            if stop["flag"]:
                sys.exit(143)
    print("[train] done")
    return params


if __name__ == "__main__":
    main()
