"""Continuous-batching serving subsystem (DESIGN.md §9, §11).

``Engine`` owns a slot-based batch over a per-slot decode-state pool;
``Scheduler`` interleaves chunked prefill with batched decode. Everything
dispatches through the existing model/kernels stack, so HQP artifacts
(``QuantizedLinear`` leaves, INT8 KV) serve unchanged. ``SamplingConfig``
drives temperature/top-k/seeded sampling on every decode surface, and
``SpecDecoder`` adds the self-speculative mode: the HQP artifact drafts,
the bf16 parent verifies (greedy output bit-identical to serial bf16).
``AdmissionController`` (§14) sheds deadline-infeasible requests at
submit; ``serving.faults`` is the deterministic chaos-injection plane.
"""
from repro.serving.admission import (AdmissionConfig, AdmissionController,
                                     Verdict)
from repro.serving.engine import (Engine, Request, RequestResult,
                                  serial_decode, summarize_results)
from repro.serving.sampling import GREEDY, SamplingConfig
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.service import (HttpFrontDoor, Service, ServiceConfig,
                                   Ticket)
from repro.serving.speculative import SpecDecoder, check_drafter_compat
from repro.serving.state_pool import init_pool, init_slot_template

__all__ = ["Engine", "Request", "RequestResult", "serial_decode",
           "summarize_results", "Scheduler", "SchedulerConfig", "init_pool",
           "init_slot_template", "GREEDY", "SamplingConfig", "SpecDecoder",
           "check_drafter_compat", "Service", "ServiceConfig", "Ticket",
           "HttpFrontDoor", "AdmissionConfig", "AdmissionController",
           "Verdict"]
