"""Per-slot decode-state pool for the continuous-batching engine.

The pool is the ``lm.init_decode_state`` pytree with two twists:

  * ``pos`` is a (n_slots,) vector — every slot advances independently;
  * every cache leaf keeps the seed layout (groups, n_slots, ...), i.e. the
    slot axis is **axis 1** of every leaf under ``state["caches"]`` (axis 0 is
    the lax.scan group stack). ``SLOT_AXIS`` pins that invariant.

Built on ``init_decode_state(..., params=...)`` so HQP-compacted artifacts —
whose pruned KV heads / Mamba channels physically shrank — size their own
pool; the engine never consults the config for cache widths.

Slot ops are pure functions (jitted by the engine):

  gather_slot(pool, slot)          -> single-slot state (batch=1, scalar pos)
  scatter_slot(pool, slot, state)  -> pool with that slot replaced
  reset_slot(pool, slot, template, pos0)
                                   -> pool with the slot reset (admission)

``gather``/``scatter`` use dynamic_slice with a *traced* slot index, so one
compiled executable serves every slot.

PAGED mode (DESIGN.md §12): ``init_paged_pool`` swaps the per-slot KV
layout for a global page arena — KV leaves become (groups, n_pages,
page_size, Hkv, hd) with NO slot axis, and each slot owns a page-table row
(host-side, ``PageAllocator``/``PrefixCache``; the engine uploads the table
per dispatch as ``state["pages"]``). Every slot op takes ``paged=True`` and
splits cache entries by kind: position-indexed KV entries live in the
shared arena (carried through whole — per-slot slicing is meaningless
there), recurrent Mamba/xLSTM entries keep the slotted layout and the
existing dynamic-slice machinery. The contiguous layout stays the
degenerate ``page_size == max_seq`` case: one page per slot, table row i =
[i+1], bit-identical outputs (the token-identity hinge for tests).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm

SLOT_AXIS = 1   # slot axis of every leaf under pool["caches"]
TRASH_PAGE = 0  # reserved physical page: inactive dispatch rows' tables are
                # redirected here so their garbage writes never touch a live
                # page (the shared arena cannot be select-masked per slot)


def is_kv_entry(entry: Any) -> bool:
    """True for a position-indexed KV cache entry (pageable); False for
    recurrent Mamba/xLSTM state (slot-resident, O(1) per slot)."""
    return isinstance(entry, dict) and ("k" in entry or "k_q" in entry)


def init_pool(cfg, n_slots: int, max_seq: int, ctx=None,
              params: Optional[dict] = None) -> Dict[str, Any]:
    """Pool for ``n_slots`` concurrent requests (per-slot ``pos``)."""
    return lm.init_decode_state(cfg, n_slots, max_seq, ctx, params=params,
                                per_slot_pos=True)


def init_paged_pool(cfg, n_slots: int, max_seq: int, ctx=None,
                    params: Optional[dict] = None, *, page_size: int,
                    total_pages: int) -> Dict[str, Any]:
    """Pool whose KV caches are a shared (total_pages, page_size) arena.

    Physical page ``TRASH_PAGE`` (0) is reserved — ``PageAllocator`` never
    hands it out — so ``total_pages`` should budget one page of slack over
    the live working set."""
    return lm.init_decode_state(cfg, n_slots, max_seq, ctx, params=params,
                                per_slot_pos=True,
                                kv_pages=(total_pages, page_size))


def init_slot_template(cfg, max_seq: int, ctx=None,
                       params: Optional[dict] = None) -> Dict[str, Any]:
    """A fresh single-slot state (batch=1, scalar pos) — the recurrent
    entries are written into the pool on admission (``reset_slot``; the KV
    entries are never read from the template — stale KV is causally
    masked), and the state shape prefill/gather round-trips."""
    return lm.init_decode_state(cfg, 1, max_seq, ctx, params=params)


def _map_entries(pool_caches, fn_kv, fn_rec, *other_caches):
    """Apply ``fn_kv`` to KV entries and ``fn_rec`` to recurrent entries,
    zipping any extra cache tuples (template/update states) leaf-wise."""
    out = []
    for i, entry in enumerate(pool_caches):
        fn = fn_kv if is_kv_entry(entry) else fn_rec
        out.append(jax.tree.map(fn, entry, *(o[i] for o in other_caches)))
    return tuple(out)


def gather_slot(pool: Dict[str, Any], slot: jax.Array,
                paged: bool = False) -> Dict[str, Any]:
    """Extract slot ``slot`` as a batch=1 ``decode_step`` state. In paged
    mode KV entries are the shared arena and pass through whole — the
    caller attaches the slot's page-table row as ``state["pages"]``."""
    sl = lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, SLOT_AXIS)
    caches = (_map_entries(pool["caches"], lambda leaf: leaf, sl)
              if paged else jax.tree.map(sl, pool["caches"]))
    pos = jax.lax.dynamic_slice(pool["pos"], (slot,), (1,))[0]
    return {"caches": caches, "pos": pos}


def scatter_slot(pool: Dict[str, Any], slot: jax.Array,
                 state: Dict[str, Any], paged: bool = False
                 ) -> Dict[str, Any]:
    """Write a batch=1 state back into slot ``slot``. In paged mode the KV
    entries of ``state`` ARE the updated arena (the paged write already
    landed through the page table) and replace the pool's wholesale."""
    upd = lambda leaf, u: jax.lax.dynamic_update_slice_in_dim(
        leaf, u.astype(leaf.dtype), slot, SLOT_AXIS)
    caches = (_map_entries(pool["caches"], lambda leaf, u: u, upd,
                           state["caches"])
              if paged else jax.tree.map(upd, pool["caches"],
                                         state["caches"]))
    pos = jax.lax.dynamic_update_slice(
        pool["pos"], jnp.reshape(state["pos"], (1,)).astype(jnp.int32),
        (slot,))
    return {"caches": caches, "pos": pos}


def reset_slot(pool: Dict[str, Any], slot: jax.Array,
               template: Dict[str, Any], pos0: jax.Array = 0,
               paged: bool = False) -> Dict[str, Any]:
    """Reset a slot for a newly admitted request: recurrent Mamba/xLSTM
    entries are scattered from the template (they genuinely need zeroing —
    recurrent state advances irreversibly) and ``pos`` drops to ``pos0``.
    KV entries are NOT touched in either layout: stale KV from the previous
    occupant sits at positions >= pos0 where every later attend masks it by
    the absolute causal limit, and prefill overwrites it before it could
    ever become visible — skipping the template scatter saves a whole-cache
    write per admission (at high admit churn, the dominant reset cost).

    ``pos0`` is 0 for a fresh prompt; a prefix-cache hit admits at the
    shared prefix length (the slot's table already maps the cached
    pages)."""
    upd = lambda leaf, u: jax.lax.dynamic_update_slice_in_dim(
        leaf, u.astype(leaf.dtype), slot, SLOT_AXIS)
    caches = _map_entries(pool["caches"], lambda leaf, u: leaf, upd,
                          template["caches"])
    pos = jax.lax.dynamic_update_slice(
        pool["pos"],
        jnp.reshape(jnp.asarray(pos0, jnp.int32), (1,)), (slot,))
    return {"caches": caches, "pos": pos}


def rollback_slots(pool: Dict[str, Any], pos: jax.Array,
                   active: jax.Array) -> Dict[str, Any]:
    """Roll each active slot's decode position back to ``pos`` (B,) after a
    speculative verify pass over-wrote K+1 candidate positions.

    Only ``pos`` moves — the cache buffers keep the rejected candidates'
    stale KV, which is safe for the same reason slot reuse is: every later
    attend masks by the absolute causal limit (``kv_pos <= start + i``), so
    positions at or past the rolled-back ``pos`` are invisible until a later
    write replaces them, and writes always precede the attend that could
    first see them. This only holds for position-indexed (KV) caches;
    recurrent Mamba/xLSTM states advance irreversibly, which is why the
    speculative decoder refuses non-attention patterns."""
    return {"caches": pool["caches"],
            "pos": jnp.where(active, pos.astype(jnp.int32), pool["pos"])}


def select_slots(new: Dict[str, Any], old: Dict[str, Any],
                 active: jax.Array, paged: bool = False) -> Dict[str, Any]:
    """Per-slot select: keep ``new`` where ``active`` (B,) bool, else ``old``.

    Applied after every batched decode step — including each iteration of
    the engine's multi-step on-device ``lax.scan``, where ``active`` is the
    live mask (slots that hit EOS or their token budget mid-scan freeze
    here) — so inactive slots are bit-untouched: without this, the dummy
    tokens fed to them would pollute their recurrent states and creep
    ``pos``.

    Paged KV entries cannot be select-masked (the arena has no slot axis):
    they pass through from ``new`` wholesale, and inactive slots are instead
    protected at dispatch time — the engine redirects their page-table rows
    to ``TRASH_PAGE``, so their garbage writes land on the reserved page and
    their live pages are never addressed at all."""
    def sel(n, o):
        mask = active.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(mask, n, o)

    caches = (_map_entries(new["caches"], lambda n, o: n, sel,
                           old["caches"])
              if paged else jax.tree.map(sel, new["caches"], old["caches"]))
    pos = jnp.where(active, new["pos"], old["pos"])
    return {"caches": caches, "pos": pos}


# --------------------------------------------------------- host-side paging
class PageAllocator:
    """Host-side free-list allocator with refcounts over the KV page arena.

    Physical page 0 is ``TRASH_PAGE`` and never allocated. Sharing is
    refcount-based: a prefix-cache hit bumps the refcount of each shared
    page (``ref``); eviction and copy-on-write drop it (``unref``), and the
    page returns to the free list when the count hits zero. Pure Python —
    allocation happens on the host between dispatches, never inside jit."""

    def __init__(self, total_pages: int):
        if total_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.total_pages = total_pages
        self.refs = np.zeros(total_pages, dtype=np.int32)
        self.refs[TRASH_PAGE] = 1   # permanently pinned
        self._free: List[int] = list(range(total_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.total_pages - 1 - len(self._free)

    def alloc(self, n: int = 1) -> List[int]:
        """Allocate ``n`` fresh pages (refcount 1). Raises MemoryError when
        the arena is exhausted — the engine catches this and evicts from the
        prefix cache before retrying."""
        if n > len(self._free):
            raise MemoryError(
                f"KV arena exhausted: want {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self.refs[p] = 1
        return out

    def ref(self, pages) -> None:
        for p in pages:
            assert self.refs[p] > 0, f"ref of dead page {p}"
            self.refs[p] += 1

    def unref(self, pages) -> None:
        for p in pages:
            assert p != TRASH_PAGE and self.refs[p] > 0, f"bad unref {p}"
            self.refs[p] -= 1
            if self.refs[p] == 0:
                self._free.append(int(p))

    def check(self) -> None:
        """Invariant check (tests): every page is either free (ref 0) or
        referenced, never both; the trash page stays pinned."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate pages on free list"
        assert TRASH_PAGE not in free and self.refs[TRASH_PAGE] == 1
        for p in range(self.total_pages):
            assert (self.refs[p] == 0) == (p in free), \
                f"page {p}: refs={self.refs[p]}, free={p in free}"


class PrefixCache:
    """Hash-keyed shared-prefix page cache (LRU).

    Keys are the raw bytes of page-aligned prompt heads: an entry for
    ``k`` pages maps ``prompt[:k*page_size].tobytes()`` to the k physical
    page ids holding that prefix's KV. Lookup walks candidate lengths
    longest-first and returns the first hit; the hit caps at
    ``align_down(prompt_len - 1, page_size)`` so at least one prompt token
    always goes through prefill (the engine needs its logits for the first
    sampled token). Hit pages are ref'd for the requesting slot — mapping
    is copy-free; the slot only prefills the tail. Prefix KV bits are
    chunking-independent (rope/projection/quantization are all per-token),
    so reuse is bit-exact regardless of how the original prompt was
    chunked."""

    def __init__(self, alloc: PageAllocator, page_size: int):
        self.alloc = alloc
        self.page_size = page_size
        self._entries: "OrderedDict[bytes, List[int]]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, prompt: np.ndarray) -> Tuple[int, List[int]]:
        """Longest page-aligned proper-prefix hit: (n_tokens, page ids),
        with every returned page ref'd for the caller. (0, []) on miss."""
        ps = self.page_size
        for k in range((len(prompt) - 1) // ps, 0, -1):
            key = np.ascontiguousarray(prompt[:k * ps]).tobytes()
            pages = self._entries.get(key)
            if pages is not None:
                self._entries.move_to_end(key)
                self.alloc.ref(pages)
                return k * ps, list(pages)
        return 0, []

    def insert(self, prompt: np.ndarray, pages: List[int],
               n_tokens: int) -> int:
        """Register every page-aligned prefix of a freshly prefilled prompt
        (``pages`` = the slot's table row, ``n_tokens`` = prompt length).
        Returns the longest number of tokens now cached — the slot's pages
        up to that point are shared and must be treated copy-on-write."""
        ps = self.page_size
        shared = 0
        for k in range(1, n_tokens // ps + 1):
            key = np.ascontiguousarray(prompt[:k * ps]).tobytes()
            if key in self._entries:
                self._entries.move_to_end(key)
            else:
                entry = list(pages[:k])
                self.alloc.ref(entry)
                self._entries[key] = entry
            shared = k * ps
        return shared

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry, unref'ing its pages. Returns
        False when the cache is empty (arena pressure is then real — the
        engine's alloc retry will raise)."""
        if not self._entries:
            return False
        _, pages = self._entries.popitem(last=False)
        self.alloc.unref(pages)
        return True

    def clear(self) -> None:
        while self.evict_lru():
            pass
