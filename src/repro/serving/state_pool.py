"""Per-slot decode-state pool for the continuous-batching engine.

The pool is the ``lm.init_decode_state`` pytree with two twists:

  * ``pos`` is a (n_slots,) vector — every slot advances independently;
  * every cache leaf keeps the seed layout (groups, n_slots, ...), i.e. the
    slot axis is **axis 1** of every leaf under ``state["caches"]`` (axis 0 is
    the lax.scan group stack). ``SLOT_AXIS`` pins that invariant.

Built on ``init_decode_state(..., params=...)`` so HQP-compacted artifacts —
whose pruned KV heads / Mamba channels physically shrank — size their own
pool; the engine never consults the config for cache widths.

Slot ops are pure functions (jitted by the engine):

  gather_slot(pool, slot)          -> single-slot state (batch=1, scalar pos)
  scatter_slot(pool, slot, state)  -> pool with that slot replaced
  reset_slot(pool, slot, template) -> pool with the slot zeroed (admission)

``gather``/``scatter`` use dynamic_slice with a *traced* slot index, so one
compiled executable serves every slot.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import lm

SLOT_AXIS = 1   # slot axis of every leaf under pool["caches"]


def init_pool(cfg, n_slots: int, max_seq: int, ctx=None,
              params: Optional[dict] = None) -> Dict[str, Any]:
    """Pool for ``n_slots`` concurrent requests (per-slot ``pos``)."""
    return lm.init_decode_state(cfg, n_slots, max_seq, ctx, params=params,
                                per_slot_pos=True)


def init_slot_template(cfg, max_seq: int, ctx=None,
                       params: Optional[dict] = None) -> Dict[str, Any]:
    """A fresh single-slot state (batch=1, scalar pos) — written into the
    pool on admission, and the state shape prefill/gather round-trips."""
    return lm.init_decode_state(cfg, 1, max_seq, ctx, params=params)


def gather_slot(pool: Dict[str, Any], slot: jax.Array) -> Dict[str, Any]:
    """Extract slot ``slot`` as a batch=1 ``decode_step`` state."""
    caches = jax.tree.map(
        lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, SLOT_AXIS),
        pool["caches"])
    pos = jax.lax.dynamic_slice(pool["pos"], (slot,), (1,))[0]
    return {"caches": caches, "pos": pos}


def scatter_slot(pool: Dict[str, Any], slot: jax.Array,
                 state: Dict[str, Any]) -> Dict[str, Any]:
    """Write a batch=1 state back into slot ``slot``."""
    caches = jax.tree.map(
        lambda leaf, upd: jax.lax.dynamic_update_slice_in_dim(
            leaf, upd.astype(leaf.dtype), slot, SLOT_AXIS),
        pool["caches"], state["caches"])
    pos = jax.lax.dynamic_update_slice(
        pool["pos"], jnp.reshape(state["pos"], (1,)).astype(jnp.int32),
        (slot,))
    return {"caches": caches, "pos": pos}


def reset_slot(pool: Dict[str, Any], slot: jax.Array,
               template: Dict[str, Any]) -> Dict[str, Any]:
    """Zero a slot for a newly admitted request (stale KV from the previous
    occupant is masked by ``pos`` anyway; the recurrent Mamba/xLSTM states
    genuinely need the reset)."""
    return scatter_slot(pool, slot, template)


def rollback_slots(pool: Dict[str, Any], pos: jax.Array,
                   active: jax.Array) -> Dict[str, Any]:
    """Roll each active slot's decode position back to ``pos`` (B,) after a
    speculative verify pass over-wrote K+1 candidate positions.

    Only ``pos`` moves — the cache buffers keep the rejected candidates'
    stale KV, which is safe for the same reason slot reuse is: every later
    attend masks by the absolute causal limit (``kv_pos <= start + i``), so
    positions at or past the rolled-back ``pos`` are invisible until a later
    write replaces them, and writes always precede the attend that could
    first see them. This only holds for position-indexed (KV) caches;
    recurrent Mamba/xLSTM states advance irreversibly, which is why the
    speculative decoder refuses non-attention patterns."""
    return {"caches": pool["caches"],
            "pos": jnp.where(active, pos.astype(jnp.int32), pool["pos"])}


def select_slots(new: Dict[str, Any], old: Dict[str, Any],
                 active: jax.Array) -> Dict[str, Any]:
    """Per-slot select: keep ``new`` where ``active`` (B,) bool, else ``old``.

    Applied after every batched decode step — including each iteration of
    the engine's multi-step on-device ``lax.scan``, where ``active`` is the
    live mask (slots that hit EOS or their token budget mid-scan freeze
    here) — so inactive slots are bit-untouched: without this, the dummy
    tokens fed to them would pollute their recurrent states and creep
    ``pos``."""
    def sel(n, o):
        mask = active.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(mask, n, o)

    caches = jax.tree.map(sel, new["caches"], old["caches"])
    pos = jnp.where(active, new["pos"], old["pos"])
    return {"caches": caches, "pos": pos}
