"""Async streaming service layer: the network front door over ``Engine``.

Two pieces (DESIGN.md §13):

``Service`` — the HTTP-free admission core, unit-testable without a socket:

  * a BOUNDED admission queue feeding ``Engine.submit`` with backpressure:
    at most ``n_slots + queue_depth`` requests are ever in flight
    (running + queued); ``submit`` returns a ``Ticket`` stream handle, or
    ``None`` when the bound is hit — the caller sheds (HTTP: 429 +
    Retry-After). The engine's own ``waiting`` list is therefore never
    longer than ``queue_depth``;
  * per-request DEADLINES (absolute, against an injectable ``clock``):
    an expired request is evicted wherever it lives — dropped from the
    queue, or ``Engine.cancel``-ed out of its slot MID-PREFILL, which in
    paged mode releases the slot's page references immediately — and its
    stream finishes with ``finish_reason="deadline"``;
  * DRAIN (``begin_drain``/``drain``): stop admitting (new submits shed
    with ``draining=True``; HTTP: 503) while every already-admitted
    request runs to completion — the SIGTERM path;
  * token streaming at host-sync granularity via ``Engine.on_token``:
    each emitted token is appended to its ticket and pushed through the
    ticket's ``sink`` callback, so a streaming transport sees tokens as
    the device produces them, not when the request finishes.

``HttpFrontDoor`` — a stdlib-asyncio HTTP/1.1 server (no third-party web
framework; the container has none) exposing the core as server-sent
events:

  POST /v1/generate   {"prompt": [ids] | "prompt_len": n,
                       "max_new_tokens": 16, "eos_id": null,
                       "deadline_s": null}
      200  text/event-stream; per token
             event: token
             data: {"index": i, "token": t}
           then exactly one
             event: done
             data: {"finish_reason": "length|eos|deadline|cancelled",
                    "n_tokens": n, "ttft_ms": ..., "latency_ms": ...}
      429  saturated, or deadline-infeasible under a warm admission
           controller (Retry-After header carries the honest estimate;
           body {"error": "saturated"|"infeasible", "retry_after_s": r})
      503  draining  (body {"error": "draining"})
      400  bad request (invalid JSON, bad/empty prompt, budget > max_seq,
           non-POST on a generate route)
      408  request not delivered within request_timeout_s (slow-loris)
      413  body exceeds max_body_bytes
     A fault-isolated request's stream terminates with ``event: error``
     (same payload shape as ``done``, finish_reason "error").
  GET /healthz | /stats
      200  {"status": "ok|draining", "slots_active": ..., "queued": ...,
            "service": {...}, "engine": {...}}
  GET /metrics
      200  text/plain Prometheus exposition: every Engine.stats /
           Service.stats key (declared in repro.telemetry.schema) plus
           the per-step phase histograms and request TTFT/latency
           histograms — rendered on the pump thread via a ("metrics",
           fut) inbox op like every other service touch.

The engine is not thread-safe and JAX dispatch must stay on one thread, so
ALL service work runs on a dedicated pump thread (``Service.step`` in a
loop). The asyncio side NEVER blocks on the pump's lock — a handler that
did would freeze the whole event loop for up to an engine step (or an XLA
compile) per request, serializing every other stream behind it. Instead
handlers post submit/cancel/health operations to a thread-safe inbox the
pump drains between steps (awaiting a future for the reply), and token
events flow back in per-step batches: sinks stage events on the pump
thread, the pump flushes each step's batch (events + replies) through ONE
``loop.call_soon_threadsafe``, and each stream coalesces its queued burst
into a single socket write. Tokens only materialize at host syncs, so the
batching adds no latency — it removes a per-token loop wakeup.
A client disconnect mid-stream cancels its request and frees the slot.
SIGTERM closes the listener, drains in-flight slots, then exits — see
``run_http``.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import json
import os
import signal
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import telemetry
from repro.serving.admission import AdmissionController
from repro.serving.engine import FREE, Engine, Request

Event = Tuple[Any, ...]   # ("token", index, token) | ("done", info_dict)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    queue_depth: int = 16           # admitted-but-unslotted bound; total
                                    # in-flight bound = n_slots + queue_depth
    default_deadline_s: Optional[float] = None   # per-request override wins
    retry_after_s: float = 0.25     # advertised on 429 responses
    telemetry: bool = True          # metrics registry + phase/latency
                                    # histograms (GET /metrics); off for the
                                    # bench overhead-control phase


class Ticket:
    """One admitted request's stream handle.

    ``tokens`` accumulates every emitted token (the identity surface the
    tests compare against ``Engine.run``); ``sink``, when set, receives
    ``("token", index, token)`` per token and one final ``("done", info)``.
    Timing fields use the service's clock."""

    def __init__(self, uid: int, deadline: Optional[float],
                 sink: Optional[Callable[[Event], None]], t_submit: float,
                 prompt_len: int = 0, max_new_tokens: int = 0):
        self.uid = uid
        self.deadline = deadline          # absolute clock value, or None
        self.sink = sink
        self.prompt_len = prompt_len      # work-remaining bookkeeping for
        self.max_new_tokens = max_new_tokens   # feasibility admission
        self.tokens: List[int] = []
        self.finish_reason: Optional[str] = None
        self.t_submit = t_submit
        self.t_first_token: Optional[float] = None
        self.t_finish: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_finish is None:
            return None
        return self.t_finish - self.t_submit


class Service:
    """Bounded-admission streaming service over one ``Engine``.

    The service owns the engine's ``on_token`` hook and its host-side
    lifecycle; callers drive it with ``submit``/``step`` (or ``drain``).
    NOT thread-safe — a multi-threaded transport must serialize access
    (``HttpFrontDoor`` gives its pump thread sole ownership and relays
    handler operations through an inbox)."""

    def __init__(self, engine: Engine, cfg: Optional[ServiceConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 admission: Optional[AdmissionController] = None):
        """``admission``: optional deadline-feasibility controller
        (serving/admission.py). When set, ``step`` feeds it the engine's
        per-step throughput and ``submit`` sheds deadlined requests the
        predictor deems infeasible — on top of (never instead of) the
        static ``n_slots + queue_depth`` hard cap."""
        self.engine = engine
        self.cfg = cfg or ServiceConfig()
        if self.cfg.queue_depth < 0:
            raise ValueError("queue_depth must be >= 0")
        self.clock = clock
        self.admission = admission
        self.tickets: Dict[int, Ticket] = {}     # live (unfinished) only
        self.draining = False
        self.stats = {"submitted": 0, "completed": 0, "shed": 0,
                      "shed_infeasible": 0, "expired": 0, "cancelled": 0,
                      "faults": 0, "queue_peak": 0}
        # why the most recent submit was shed — the transport reads this
        # for its status code and (honest) Retry-After
        self.last_shed: Dict[str, Any] = {}
        engine.on_token = self._on_token
        # ONE clock drives the whole plane: lifecycle timestamps, span
        # recording, and phase attribution all read the service's
        # injectable clock once the engine is attached (tests inject a
        # fake clock here and everything downstream stays deterministic)
        engine.clock = self.clock
        self.registry: Optional[telemetry.MetricsRegistry] = None
        self._phase_hists: Dict[str, telemetry.Histogram] = {}
        self._ttft_hist: Optional[telemetry.Histogram] = None
        self._latency_hist: Optional[telemetry.Histogram] = None
        if self.cfg.telemetry:
            sch = telemetry.schema
            reg = self.registry = telemetry.MetricsRegistry()
            reg.register_stats(sch.SERVICE_PREFIX, self.stats,
                               sch.SERVICE_STATS)
            reg.register_stats(sch.ENGINE_PREFIX, engine.stats,
                               sch.ENGINE_STATS)
            for phase in sch.PHASES:
                self._phase_hists[phase] = reg.histogram(
                    sch.PHASE_HISTOGRAM,
                    "per-engine-step wall time by phase (seconds)",
                    buckets=sch.PHASE_BUCKETS_S, phase=phase)
            self._ttft_hist = reg.histogram(
                sch.TTFT_HISTOGRAM,
                "submit-to-first-token latency (seconds)",
                buckets=sch.LATENCY_BUCKETS_S)
            self._latency_hist = reg.histogram(
                sch.LATENCY_HISTOGRAM,
                "submit-to-finish latency (seconds)",
                buckets=sch.LATENCY_BUCKETS_S)

    def render_metrics(self) -> str:
        """Prometheus text exposition of every stat + histogram. Called
        on whatever thread owns the service (the pump, for the HTTP
        front door) — rendering reads the live dicts directly."""
        if self.registry is None:
            return "# telemetry disabled (ServiceConfig.telemetry=False)\n"
        return self.registry.render()

    # ------------------------------------------------------------- admission
    @property
    def load(self) -> int:
        """Admitted-but-unfinished requests (queued + running)."""
        return len(self.tickets)

    @property
    def capacity(self) -> int:
        return self.engine.n_slots + self.cfg.queue_depth

    @property
    def saturated(self) -> bool:
        return self.load >= self.capacity

    def _backlog_tokens(self) -> Tuple[int, int]:
        """(prefill, decode) tokens of work still owed to live tickets —
        the backlog a new admission queues behind. Prefill remaining is
        exact for slotted requests (the engine tracks ``prefill_done``)
        and the full prompt for queued ones."""
        prefilled = {}
        for s in self.engine.slots:
            if s.stage != FREE and s.result is not None:
                prefilled[s.result.uid] = s.prefill_done
        prefill = decode = 0
        for t in self.tickets.values():
            prefill += max(0, t.prompt_len - prefilled.get(t.uid, 0))
            decode += max(0, t.max_new_tokens - len(t.tokens))
        return prefill, decode

    def _retry_after(self) -> float:
        """Retry-After for a saturation shed: with a warm controller, the
        mean time for one in-flight request to drain (backlog work time /
        live requests) — a queue position should open around then; the
        static ``cfg.retry_after_s`` otherwise."""
        if self.admission is None or not self.admission.warm or not self.load:
            return self.cfg.retry_after_s
        pf, dec = self._backlog_tokens()
        return self.admission.clamp_retry(
            self.admission.work_s(pf, dec) / self.load)

    def submit(self, request: Request,
               deadline_s: Optional[float] = None,
               sink: Optional[Callable[[Event], None]] = None
               ) -> Optional[Ticket]:
        """Admit a request, or return None to shed — ``self.last_shed``
        tells the transport why (``draining`` / ``saturated`` /
        ``infeasible``) and what Retry-After to advertise. Invalid
        requests (empty prompt, budget > max_seq) raise ``ValueError``
        straight from ``Engine.submit``."""
        if self.draining:
            self.stats["shed"] += 1
            self.last_shed = {"reason": "draining", "retry_after_s": None}
            self._trace_shed("draining")
            return None
        if self.saturated:
            self.stats["shed"] += 1
            self.last_shed = {"reason": "saturated",
                              "retry_after_s": self._retry_after()}
            self._trace_shed("saturated")
            return None
        if deadline_s is None:
            deadline_s = self.cfg.default_deadline_s
        prompt_len = len(request.prompt)
        if (deadline_s is not None and self.admission is not None
                and self.admission.warm):
            verdict = self.admission.feasible(
                prompt_len, request.max_new_tokens,
                self._backlog_tokens(), deadline_s)
            if not verdict.feasible:
                # shed NOW, at submit — before the request burns a queue
                # position and slot time only to die in the deadline sweep
                self.stats["shed"] += 1
                self.stats["shed_infeasible"] += 1
                self.last_shed = {"reason": "infeasible",
                                  "retry_after_s": verdict.retry_after_s,
                                  "predicted_s": verdict.predicted_s}
                self._trace_shed("infeasible")
                return None
        now = self.clock()
        uid = self.engine.submit(request)
        ticket = Ticket(uid,
                        None if deadline_s is None else now + deadline_s,
                        sink, now, prompt_len=prompt_len,
                        max_new_tokens=request.max_new_tokens)
        self.tickets[uid] = ticket
        self.stats["submitted"] += 1
        self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                       len(self.engine.waiting))
        return ticket

    def _trace_shed(self, reason: str) -> None:
        """Record a shed on the engine's span recorder, if one is
        attached — sheds never reach the engine, so only the service can
        put them on the trace timeline."""
        if self.engine.tracer is not None:
            self.engine.tracer.shed(self.clock(), reason)

    # ------------------------------------------------------------- lifecycle
    def _on_token(self, uid: int, tok: int) -> None:
        t = self.tickets.get(uid)
        if t is None:        # a bare Engine.run on the side — not ours
            return
        if not t.tokens:
            t.t_first_token = self.clock()
        t.tokens.append(tok)
        if t.sink is not None:
            t.sink(("token", len(t.tokens) - 1, tok))

    def _finish(self, ticket: Ticket, reason: str, counter: str) -> None:
        ticket.finish_reason = reason
        ticket.t_finish = self.clock()
        self.tickets.pop(ticket.uid, None)
        self.stats[counter] += 1
        if self._latency_hist is not None:
            self._latency_hist.observe(ticket.latency_s)
            if ticket.ttft_s is not None:
                self._ttft_hist.observe(ticket.ttft_s)
        if ticket.sink is not None:
            lat = ticket.latency_s
            ttft = ticket.ttft_s
            ticket.sink(("done", {
                "finish_reason": reason,
                "n_tokens": len(ticket.tokens),
                "ttft_ms": None if ttft is None else ttft * 1e3,
                "latency_ms": None if lat is None else lat * 1e3,
            }))

    def cancel(self, uid: int) -> bool:
        """Abort a live request (client disconnect). Frees its slot/queue
        position (and pages, in paged mode) immediately."""
        ticket = self.tickets.get(uid)
        if ticket is None:
            return False
        self.engine.cancel(uid)
        self._finish(ticket, "cancelled", "cancelled")
        return True

    def expire_deadlines(self) -> int:
        """Evict every live request whose deadline has passed — queued OR
        mid-flight (mid-prefill eviction frees the slot's pages at once).
        Runs at the top of every ``step``; returns how many expired."""
        now = self.clock()
        expired = [t for t in self.tickets.values()
                   if t.deadline is not None and now > t.deadline]
        for t in expired:
            self.engine.cancel(t.uid)
            self._finish(t, "deadline", "expired")
        return len(expired)

    @property
    def has_work(self) -> bool:
        return self.engine.has_work

    def _fail_all(self) -> None:
        """Last-resort blast radius for an *unattributable* engine fault:
        cancel every live request (pages freed via ``Engine.cancel``) and
        finish their streams with ``error`` — the pump survives with a
        clean engine rather than dying mid-stream."""
        for t in list(self.tickets.values()):
            self.engine.cancel(t.uid)
            self._finish(t, "error", "faults")

    def step(self) -> int:
        """One service tick: deadline sweep, one engine tick, route
        finished results to their tickets. Returns finished count.

        Faults: the engine already scopes per-request failures (their
        results arrive with ``finish_reason="error"``); anything that
        still escapes ``Engine.step`` is absorbed here by failing every
        live request — one poisoned tick must never kill the owner
        thread. The engine's own per-step measurement
        (``Engine.last_step``) feeds BOTH the admission controller's
        EWMAs and the phase histograms — one clock read per step, two
        consumers, no service-side re-timing."""
        self.expire_deadlines()
        if not self.engine.has_work:
            return 0
        n = 0
        try:
            results = self.engine.step()
        except Exception:
            self.stats["faults"] += 1
            self._fail_all()
            return 0
        last = self.engine.last_step
        if self.admission is not None:
            self.admission.observe_step(last)
        if self._phase_hists and last:
            for phase, dt in last["phases"].items():
                h = self._phase_hists.get(phase)
                if h is not None:
                    h.observe(dt)
        for res in results:
            ticket = self.tickets.get(res.uid)
            if ticket is not None:
                if res.finish_reason == "error":
                    self._finish(ticket, "error", "faults")
                else:
                    self._finish(ticket, res.finish_reason, "completed")
                n += 1
        return n

    def begin_drain(self) -> None:
        """Stop admitting; in-flight and queued requests keep running."""
        self.draining = True

    def drain(self) -> None:
        """``begin_drain`` + run every admitted request to completion
        (deadline expiry still applies — a drain can never hang on a
        deadlined request)."""
        self.begin_drain()
        while self.has_work:
            self.step()


# ---------------------------------------------------------------- HTTP layer
_SSE_HEADERS = (b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n"
                b"X-Accel-Buffering: no\r\n"
                b"Connection: close\r\n\r\n")


def sse_event(name: str, data: dict) -> bytes:
    return (f"event: {name}\ndata: {json.dumps(data)}\n\n").encode()


class _BodyTooLarge(Exception):
    """Request body exceeds the front door's cap (maps to 413)."""

    def __init__(self, n: int):
        super().__init__(f"body too large: {n} bytes")
        self.n = n


def _plain_response(status: str, body: dict,
                    extra_headers: Tuple[str, ...] = ()) -> bytes:
    payload = json.dumps(body).encode()
    head = [f"HTTP/1.1 {status}",
            "Content-Type: application/json",
            f"Content-Length: {len(payload)}",
            "Connection: close", *extra_headers]
    return ("\r\n".join(head) + "\r\n\r\n").encode() + payload


def _text_response(status: str, text: str, content_type: str) -> bytes:
    payload = text.encode()
    head = [f"HTTP/1.1 {status}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(payload)}",
            "Connection: close"]
    return ("\r\n".join(head) + "\r\n\r\n").encode() + payload


# what GET /metrics advertises — the version-tagged Prometheus text format
_EXPOSITION_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class HttpFrontDoor:
    """asyncio HTTP/1.1 + SSE transport over a ``Service``.

    Single-owner concurrency: the pump thread owns ALL service/engine
    access (``self.lock`` guards it only against the shutdown path).
    Handler coroutines never touch the service directly — they post
    ``("submit", ...)``/``("cancel", ...)``/``("health", ...)`` operations
    to ``self._inbox`` and await a future; the pump drains the inbox
    between engine steps, so the event loop is never blocked behind a
    multi-millisecond step (or a surprise XLA compile) and admission
    decisions stay strictly serialized with ticks. ``start()`` binds the
    listener (``port=0`` picks a free port, re-read from ``self.port``)
    and starts the pump; ``stop()`` closes the listener, optionally
    drains, and joins the pump."""

    def __init__(self, service: Service, host: str = "127.0.0.1",
                 port: int = 8080, pump_idle_s: float = 0.001,
                 log: Callable[[str], None] = lambda s: None,
                 max_body_bytes: int = 1 << 20,
                 request_timeout_s: float = 10.0,
                 watchdog_s: Optional[float] = None,
                 on_wedged: Optional[Callable[[str], None]] = None):
        """``max_body_bytes`` caps request bodies (413 beyond it);
        ``request_timeout_s`` bounds how long a client may take to
        deliver a full request (408 beyond it — the slow-loris defense).
        ``watchdog_s`` arms the pump watchdog: if the pump thread makes
        no progress for that long (a wedged engine step — XLA deadlock,
        a hung host callback), ``on_wedged`` fires; the default logs and
        ``os._exit(2)``s, because a wedged engine cannot be drained and a
        clean nonzero exit beats a silent hang (tests inject a recorder
        instead)."""
        self.service = service
        self.host = host
        self.port = port
        self.pump_idle_s = pump_idle_s
        self.log = log
        self.max_body_bytes = max_body_bytes
        self.request_timeout_s = request_timeout_s
        self.watchdog_s = watchdog_s
        self.on_wedged = on_wedged or self._exit_wedged
        # the heartbeat measures REAL wall time even under an injected test
        # clock: the watchdog exists to catch a wedged pump thread, and a
        # frozen fake clock must not mask one. telemetry.wall_clock is the
        # one sanctioned raw-clock read in serving (see its docstring).
        self._beat = telemetry.wall_clock()
        self.lock = threading.Lock()
        self._stop_pump = threading.Event()
        self._kick = threading.Event()       # wakes an idle-parked pump
        self._pump_thread: Optional[threading.Thread] = None
        self._watchdog_thread: Optional[threading.Thread] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._active_streams = 0
        # handler -> pump operations; deque appends/pops are atomic so no
        # extra lock is needed on the hot path
        self._inbox: Deque[Tuple[Any, ...]] = collections.deque()
        # pump -> loop: events staged by sinks (grouped per stream queue),
        # flushed in ONE call_soon_threadsafe per engine step — a decode
        # scan emits decode_steps x n_slots tokens per host sync, and
        # waking the loop per token (a self-pipe write each) costs more
        # than the tokens; grouping here also makes the loop-side queue
        # traffic per-stream-per-step instead of per-token
        self._staged: Dict[asyncio.Queue, List[Event]] = {}
        self._replies: List[Tuple[asyncio.Future, Any]] = []
        # prompt_len synthesis (curl/load-tool convenience, mirrors the
        # JSONL trace loader's contract)
        self._rng = np.random.RandomState(0)

    # -------------------------------------------------------------- lifecycle
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._pump_thread = threading.Thread(target=self._pump, daemon=True,
                                             name="engine-pump")
        self._pump_thread.start()
        if self.watchdog_s:
            self._watchdog_thread = threading.Thread(
                target=self._watch, daemon=True, name="pump-watchdog")
            self._watchdog_thread.start()

    def _pump(self) -> None:
        """Engine thread: drain handler operations, step whenever there is
        work, park briefly when idle (a ``_kick`` wakes it early). Serving
        the inbox and stepping on one thread keeps submit/cancel strictly
        between ticks — the same interleaving the sync tests drive by
        hand. Each iteration flushes everything it staged (token events +
        operation replies) to the event loop in one batch."""
        while not self._stop_pump.is_set():
            # wall time on purpose — see _beat in __init__
            self._beat = telemetry.wall_clock()
            with self.lock:
                self._serve_inbox()
                busy = self.service.has_work
                if busy:
                    self.service.step()
                staged, self._staged = self._staged, {}
                replies, self._replies = self._replies, []
            if staged or replies:
                self._loop.call_soon_threadsafe(self._flush, staged, replies)
            if not busy:
                self._kick.wait(self.pump_idle_s)
                self._kick.clear()

    def _exit_wedged(self, msg: str) -> None:
        """Default wedged-pump escalation: a hung engine step cannot be
        drained (the pump owns the only thread allowed to touch it), so
        log loudly and exit with a clean nonzero status — supervisors
        restart on exit codes, not on silence."""
        self.log(msg)
        os._exit(2)

    def _watch(self) -> None:
        """Watchdog thread: the pump stamps ``_beat`` every iteration
        (idle parks are sub-millisecond), so a stale heartbeat means one
        engine step / inbox op has been stuck for ``watchdog_s``."""
        period = min(max(self.watchdog_s / 4.0, 0.01), 1.0)
        while not self._stop_pump.wait(period):
            # wall time on purpose — see _beat in __init__
            stale = telemetry.wall_clock() - self._beat
            if stale > self.watchdog_s:
                self.on_wedged(
                    f"[http] WATCHDOG: pump made no progress for "
                    f"{stale:.1f}s (> {self.watchdog_s:g}s) — engine step "
                    f"wedged; cannot drain, exiting 2")
                return

    def _serve_inbox(self) -> None:
        """Apply queued handler operations (pump thread, lock held)."""
        svc = self.service
        while self._inbox:
            op = self._inbox.popleft()
            if op[0] == "submit":
                _, req, deadline_s, sink, fut = op
                try:
                    ticket = svc.submit(req, deadline_s=deadline_s,
                                        sink=sink)
                    res: Any = (ticket, None if ticket is not None
                                else dict(svc.last_shed))
                except ValueError as e:
                    res = e
                self._replies.append((fut, res))
            elif op[0] == "cancel":
                svc.cancel(op[1])
            elif op[0] == "health":
                self._replies.append((op[1], self._snapshot()))
            elif op[0] == "metrics":
                # rendered HERE so the exposition is a consistent
                # between-steps snapshot — handlers never read live dicts
                self._replies.append((op[1], svc.render_metrics()))
            elif op[0] == "drain":
                svc.begin_drain()
                self._replies.append((op[1], True))
            else:                                    # ("idle", fut)
                self._replies.append((op[1], not svc.has_work))

    @staticmethod
    def _flush(staged: Dict[asyncio.Queue, List[Event]],
               replies: List[Tuple[asyncio.Future, Any]]) -> None:
        for queue, evs in staged.items():
            queue.put_nowait(evs)              # one item per stream per step
        for fut, value in replies:
            if not fut.done():
                if isinstance(value, Exception):
                    fut.set_exception(value)
                else:
                    fut.set_result(value)

    async def _ask(self, op: Tuple[Any, ...]) -> Any:
        """Post an operation needing a reply; the last element must be a
        fresh future from this loop."""
        self._inbox.append(op)
        self._kick.set()
        return await op[-1]

    async def stop(self, drain: bool = True) -> None:
        """Close the listener; with ``drain`` run every admitted request to
        completion (the pump keeps stepping) and let open streams flush
        their final events before the pump stops. Goes through the inbox
        like every other service touch, so the loop stays responsive (and
        keeps delivering final events) throughout shutdown."""
        await self._ask(("drain", self._loop.create_future()))
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if drain:
            while True:
                idle = await self._ask(("idle", self._loop.create_future()))
                if idle and self._active_streams == 0:
                    break
                await asyncio.sleep(0.002)
        self._stop_pump.set()
        self._kick.set()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=10)
        if self._watchdog_thread is not None:
            self._watchdog_thread.join(timeout=10)

    # --------------------------------------------------------------- handler
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._active_streams += 1
        try:
            try:
                method, path, body = await asyncio.wait_for(
                    self._read_request(reader), self.request_timeout_s)
            except asyncio.TimeoutError:
                # slow-loris: the client dribbled bytes slower than the
                # request timeout — answer and hang up, never touching
                # the pump
                writer.write(_plain_response(
                    "408 Request Timeout",
                    {"error": "request not received in "
                              f"{self.request_timeout_s:g}s"}))
                return
            except _BodyTooLarge as e:
                writer.write(_plain_response(
                    "413 Payload Too Large",
                    {"error": f"body of {e.n} bytes exceeds "
                              f"{self.max_body_bytes}"}))
                return
            except (asyncio.IncompleteReadError, ValueError):
                writer.write(_plain_response(
                    "400 Bad Request", {"error": "malformed request"}))
                return
            if method == "GET" and path in ("/healthz", "/stats"):
                writer.write(_plain_response("200 OK", await self._health()))
            elif method == "GET" and path == "/metrics":
                writer.write(_text_response("200 OK", await self._metrics(),
                                            _EXPOSITION_CONTENT_TYPE))
            elif path in ("/v1/generate", "/generate"):
                if method != "POST":
                    writer.write(_plain_response(
                        "400 Bad Request",
                        {"error": f"use POST for {path}, not {method}"}))
                else:
                    await self._generate(writer, body)
            else:
                writer.write(_plain_response(
                    "404 Not Found", {"error": f"no route {method} {path}"}))
        finally:
            self._active_streams -= 1
            try:
                await writer.drain()
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        line = (await reader.readline()).decode("latin-1").rstrip("\r\n")
        parts = line.split(" ")
        if len(parts) != 3:
            raise ValueError(f"bad request line {line!r}")
        method, path = parts[0], parts[1]
        headers = {}
        while True:
            h = await reader.readline()   # StreamReader's own line limit
            if h in (b"\r\n", b"\n", b""):     # turns absurd headers into
                break                          # ValueError -> 400
            if len(headers) > 100:
                raise ValueError("too many headers")
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or 0)
        if n > self.max_body_bytes:
            raise _BodyTooLarge(n)             # -> 413, body never read
        body = await reader.readexactly(n) if n else b""
        return method, path, body

    def _snapshot(self) -> dict:
        """Health/stats payload (pump thread, lock held)."""
        svc = self.service
        return {"status": "draining" if svc.draining else "ok",
                "slots_active": svc.engine.n_active,
                "queued": len(svc.engine.waiting),
                "capacity": svc.capacity,
                "service": dict(svc.stats),
                "engine": {k: int(v) for k, v in
                           svc.engine.stats.items()}}

    async def _health(self) -> dict:
        return await self._ask(("health", self._loop.create_future()))

    async def _metrics(self) -> str:
        return await self._ask(("metrics", self._loop.create_future()))

    def _parse_request(self, body: bytes) -> Tuple[Request, Optional[float]]:
        """Parse + validate a generate body; every rejection raises here,
        BEFORE the pump is involved — a malformed request must cost the
        event loop a 400, never an engine exception."""
        max_seq = self.service.engine.max_seq
        d = json.loads(body.decode() or "{}")
        if not isinstance(d, dict):
            raise ValueError("body must be a JSON object")
        if "prompt" in d:
            prompt = d["prompt"]
            if (not isinstance(prompt, list) or not prompt
                    or not all(isinstance(t, int) and not isinstance(t, bool)
                               for t in prompt)):
                raise ValueError("'prompt' must be a non-empty list of "
                                 "token ids")
        elif "prompt_len" in d:
            n = int(d["prompt_len"])
            if not (1 <= n <= max_seq):
                raise ValueError(f"prompt_len must be in [1, {max_seq}]")
            vocab = self.service.engine.cfg.vocab_size
            prompt = self._rng.randint(0, vocab, n).tolist()
        else:
            raise ValueError("body needs 'prompt' (token ids) or "
                             "'prompt_len'")
        req = Request(prompt=prompt,
                      max_new_tokens=int(d.get("max_new_tokens", 16)),
                      eos_id=d.get("eos_id"))
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + req.max_new_tokens > max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds max_seq={max_seq}")
        deadline_s = d.get("deadline_s")
        return req, (None if deadline_s is None else float(deadline_s))

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            req, deadline_s = self._parse_request(body)
        except (json.JSONDecodeError, ValueError, TypeError, KeyError) as e:
            writer.write(_plain_response("400 Bad Request",
                                         {"error": str(e)}))
            return
        queue: asyncio.Queue = asyncio.Queue()

        def sink(ev: Event) -> None:
            # runs on the pump thread mid-step; the pump flushes the batch
            # to the loop after the step (it swaps in a fresh dict each
            # step, so always dereference self._staged)
            self._staged.setdefault(queue, []).append(ev)

        try:
            ticket, shed = await self._ask(
                ("submit", req, deadline_s, sink,
                 self._loop.create_future()))
        except ValueError as e:
            writer.write(_plain_response("400 Bad Request",
                                         {"error": str(e)}))
            return
        if ticket is None:
            reason = (shed or {}).get("reason", "saturated")
            if reason == "draining":
                writer.write(_plain_response(
                    "503 Service Unavailable", {"error": "draining"}))
            else:
                # saturated or deadline-infeasible; Retry-After is the
                # service's honest estimate when the admission controller
                # is warm, its static default otherwise
                retry = (shed or {}).get("retry_after_s")
                if retry is None:
                    retry = self.service.cfg.retry_after_s
                body_out = {"error": reason, "retry_after_s": retry}
                if "predicted_s" in (shed or {}):
                    body_out["predicted_s"] = round(shed["predicted_s"], 4)
                writer.write(_plain_response(
                    "429 Too Many Requests", body_out,
                    extra_headers=(f"Retry-After: {retry:g}",)))
            return
        writer.write(_SSE_HEADERS)
        try:
            await writer.drain()
            while True:
                # each queue item is one step's event batch for this
                # stream (up to decode_steps tokens); coalesce any backlog
                # into a single write + drain
                burst = list(await queue.get())
                while not queue.empty():
                    burst.extend(queue.get_nowait())
                out = bytearray()
                finished = False
                for ev in burst:
                    if ev[0] == "token":
                        # hot path: bytes %-format, no json round-trip
                        out += (b'event: token\n'
                                b'data: {"index": %d, "token": %d}\n\n'
                                % (ev[1], int(ev[2])))
                    else:
                        # a fault-isolated request ends its stream with
                        # event: error instead of done (same payload shape)
                        name = ("error"
                                if ev[1].get("finish_reason") == "error"
                                else "done")
                        out += sse_event(name, ev[1])
                        finished = True
                writer.write(bytes(out))
                await writer.drain()
                if finished:
                    return
        except (ConnectionResetError, BrokenPipeError, OSError):
            # client went away mid-stream: free the slot immediately
            self._inbox.append(("cancel", ticket.uid))
            self._kick.set()


def run_http(service: Service, host: str = "127.0.0.1", port: int = 8080,
             log: Callable[[str], None] = print,
             watchdog_s: Optional[float] = None) -> None:
    """Blocking entrypoint for ``serve --http``: listen until SIGTERM (or
    SIGINT), then drain in-flight slots before returning — the graceful
    shutdown contract CI's http-smoke asserts. ``watchdog_s`` arms the
    pump watchdog (a wedged engine step exits 2 instead of hanging)."""
    door = HttpFrontDoor(service, host=host, port=port, log=log,
                         watchdog_s=watchdog_s)

    async def main() -> None:
        await door.start()
        eng = service.engine
        log(f"[http] listening on http://{door.host}:{door.port} "
            f"(slots={eng.n_slots}, queue_depth={service.cfg.queue_depth}, "
            f"deadline_s={service.cfg.default_deadline_s})")
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGTERM, signal.SIGINT):
            loop.add_signal_handler(sig, stop.set)
        await stop.wait()
        log("[http] shutdown signal: closing listener, draining "
            f"{service.load} in-flight request(s)")
        await door.stop(drain=True)
        log(f"[http] drained cleanly: served {service.stats['completed']} "
            f"requests ({service.stats['shed']} shed, "
            f"{service.stats['expired']} deadline-expired)")

    asyncio.run(main())
