"""Scheduling policy for the continuous-batching engine.

The scheduler is pure host-side policy: it looks at slot metadata and picks
the next device action. Invariants (see DESIGN.md §9):

  * one prefill *chunk* per tick, never a whole prompt — chunked prefill is
    what bounds the decode stall other requests see while a long prompt is
    admitted (HALP's point: measure latency under the real serving regime);
  * prefill has priority over decode (round-robin across prefilling slots),
    so a newly admitted request reaches its first token in
    ceil(prompt/chunk) ticks regardless of how many slots are decoding;
  * decode is one batched dispatch over *all* decoding slots — slots never
    run separate decode dispatches — and each dispatch runs ``decode_steps``
    device steps before syncing tokens back to the host;
  * every KV attend carries a static visible window: the live length bound
    bucketed up to ``window_block`` (``visible_window``), so attend traffic
    and compile count both stay bounded;
  * admission is eager: a free slot + a waiting request always admits before
    the tick's action is chosen (the engine owns admission; the scheduler
    only sequences work already placed in slots).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

PREFILL = "prefill"
DECODE = "decode"
IDLE = "idle"


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    prefill_chunk: int = 16     # max prompt tokens per prefill dispatch
    decode_steps: int = 4       # device decode steps per host sync (lax.scan
                                # length inside Engine._decode_fn; 1 = the
                                # per-tick-sync legacy behavior)
    window_block: int = 16      # visible-window bucket: KV attends read
                                # ceil(needed/window_block) blocks, and each
                                # distinct bucket compiles one executable
                                # (<= max_seq/window_block variants total)


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str                             # "prefill" | "decode" | "idle"
    slot: Optional[int] = None            # prefill: which slot
    slots: Tuple[int, ...] = ()           # decode: which slots step


class Scheduler:
    """Round-robin chunked prefill interleaved with batched decode."""

    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        self.cfg = cfg or SchedulerConfig()
        self._rr = 0                       # round-robin cursor over slots

    def next_action(self, prefilling: Sequence[int],
                    decoding: Sequence[int]) -> Action:
        """``prefilling``/``decoding``: slot indices by lifecycle stage."""
        if prefilling:
            order = sorted(prefilling)
            pick = next((s for s in order if s >= self._rr), order[0])
            self._rr = pick + 1
            return Action(PREFILL, slot=pick)
        self._rr = 0
        if decoding:
            return Action(DECODE, slots=tuple(sorted(decoding)))
        return Action(IDLE)

    def chunk_bounds(self, prompt_len: int, done: int) -> Tuple[int, int]:
        """Next prefill chunk [lo, hi) for a prompt with ``done`` tokens
        already in the cache. The final chunk keeps its exact remainder
        length (no padding: padded prompt tokens would alter outputs)."""
        lo = done
        hi = min(prompt_len, done + self.cfg.prefill_chunk)
        return lo, hi

    def visible_window(self, needed: int, max_seq: int,
                       page_multiple: int = 0) -> int:
        """Static KV-attend window for a dispatch that reads cache positions
        [0, needed): ``needed`` bucketed up to a ``window_block`` multiple
        (bounding recompiles) and clamped to the cache capacity.

        ``page_multiple`` (paged-KV engines pass their page size) rounds the
        bucketed window up to a whole-page multiple so the page-table prefix
        the attend walks is block-aligned — without it every distinct
        (window % page_size) residue would compile its own gather. The
        rounded window may exceed ``max_seq``; the page-table prefix clamps
        to the table width and out-of-window positions mask to exact
        zeros."""
        wb = self.cfg.window_block
        w = min(max_seq, max(wb, -(-needed // wb) * wb))
        if page_multiple:
            w = -(-w // page_multiple) * page_multiple
        return w
