"""Deterministic fault injection for the serving stack.

The chaos harness (``benchmarks/run.py::bench_chaos``,
``scripts/chaos_smoke.py``, and the isolation tests) needs faults that
are *repeatable*: "the 3rd page allocation fails", "the 2nd decode
dispatch raises", "this client vanishes after 4 tokens" — never "fail
randomly at 1%".  Every injector here is counted or seeded, so a chaos
run that trips an invariant can be replayed exactly.

Engine-side injectors (wrap a live ``Engine`` in place, return a
``FaultHandle`` whose ``restore()`` puts the original back):

  inject_alloc_failure(engine, at=N)   the Nth ``PageAllocator.alloc``
                                       call raises ``MemoryError`` —
                                       arena exhaustion at a chosen
                                       moment (admission, mid-decode
                                       growth, or CoW)
  inject_decode_fault(engine, at=N)    the Nth decode dispatch raises
                                       ``InjectedFault`` BEFORE invoking
                                       the jitted callable — the donated
                                       pool is untouched, modelling a
                                       host-side failure in the dispatch
                                       path
  inject_prefill_fault(engine, at=N)   same for prefill dispatches

Raising *before* the jitted call is deliberate: it leaves the pool
valid, exercising the engine's per-request isolation (fail the culprit
slots, keep everything else).  A fault that fires mid-execution with
donated buffers is the pool-rebuild path — the engine detects deleted
leaves and fails every active slot; tests drive that by raising from an
``on_token`` hook instead.

Client-side chaos (plain blocking sockets, so the subprocess smoke and
in-process tests share one implementation):

  storm_deadlines(seed, n, lo_s, hi_s)   seeded deadline storm
  http_disconnect_mid_stream(...)        start an SSE stream, vanish
                                         after N token events
  http_slow_loris(...)                   dribble a partial request
                                         slower than the server's read
                                         timeout
  http_malformed(...)                    raw bytes on the socket, return
                                         the status line the server sent
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct
from typing import Callable, List, Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by counted injectors — distinguishable from organic faults."""


@dataclasses.dataclass
class FaultHandle:
    """Live injector state: ``calls`` counts invocations seen, ``fired``
    how many times the fault actually raised. ``restore()`` reinstalls
    the wrapped original (idempotent)."""
    kind: str
    at: int
    times: int
    calls: int = 0
    fired: int = 0
    _restore: Optional[Callable[[], None]] = None

    def restore(self) -> None:
        if self._restore is not None:
            self._restore()
            self._restore = None


def _counted(handle: FaultHandle, fn, exc_factory):
    """Wrap ``fn``: invocations ``at .. at+times-1`` (1-based) raise
    instead of calling through."""
    def wrapper(*args, **kwargs):
        handle.calls += 1
        if handle.at <= handle.calls < handle.at + handle.times:
            handle.fired += 1
            raise exc_factory()
        return fn(*args, **kwargs)
    return wrapper


def inject_alloc_failure(engine, at: int = 1, times: int = 1) -> FaultHandle:
    """Force ``MemoryError`` on the Nth (1-based) ``alloc.alloc`` call.

    Note the engine's ``_alloc_pages`` retries after evicting a prefix-
    cache entry — with a warm prefix cache a single injected failure can
    be absorbed by an eviction; pass ``times`` > 1 (or run with the
    prefix cache off) to guarantee the fault surfaces."""
    if engine.alloc is None:
        raise ValueError("alloc injection needs a paged engine")
    h = FaultHandle("alloc", at, times)
    orig = engine.alloc.alloc
    engine.alloc.alloc = _counted(
        h, orig, lambda: MemoryError(f"injected: alloc #{h.calls} denied"))

    def _restore(alloc=engine.alloc, orig=orig):
        alloc.alloc = orig
    h._restore = _restore
    return h


def _inject_dispatch(engine, attr: str, kind: str, at: int, times: int,
                     exc) -> FaultHandle:
    h = FaultHandle(kind, at, times)
    orig = getattr(engine, attr)
    setattr(engine, attr, _counted(
        h, orig, lambda: exc(f"injected: {kind} dispatch #{h.calls}")))

    def _restore(engine=engine, attr=attr, orig=orig):
        setattr(engine, attr, orig)
    h._restore = _restore
    return h


def inject_decode_fault(engine, at: int = 1, times: int = 1,
                        exc=InjectedFault) -> FaultHandle:
    """The Nth decode dispatch raises before touching the device."""
    return _inject_dispatch(engine, "_decode_fn", "decode", at, times, exc)


def inject_prefill_fault(engine, at: int = 1, times: int = 1,
                         exc=InjectedFault) -> FaultHandle:
    """The Nth prefill dispatch raises before touching the device."""
    return _inject_dispatch(engine, "_prefill_fn", "prefill", at, times, exc)


# ------------------------------------------------------------- deadline storm
def storm_deadlines(seed: int, n: int, lo_s: float, hi_s: float
                    ) -> List[float]:
    """Seeded per-request deadlines for a deadline storm — uniform in
    ``[lo_s, hi_s)``, reproducible by seed."""
    rng = np.random.RandomState(seed)
    return [float(d) for d in rng.uniform(lo_s, hi_s, size=n)]


# --------------------------------------------------------- client-side chaos
def _connect(host: str, port: int, timeout_s: float) -> socket.socket:
    s = socket.create_connection((host, port), timeout=timeout_s)
    s.settimeout(timeout_s)
    return s


def _post_bytes(path: str, body: bytes) -> bytes:
    return (f"POST {path} HTTP/1.1\r\nHost: x\r\n"
            f"Content-Length: {len(body)}\r\n\r\n").encode() + body


def http_malformed(host: str, port: int, payload: bytes,
                   timeout_s: float = 10.0) -> str:
    """Write raw ``payload`` to the server, return the status line it
    answered with ('' if it closed without answering)."""
    with _connect(host, port, timeout_s) as s:
        s.sendall(payload)
        try:
            s.shutdown(socket.SHUT_WR)
        except OSError:
            pass
        try:
            head = s.recv(4096)
        except (socket.timeout, OSError):
            return ""
    return head.split(b"\r\n", 1)[0].decode("latin-1", "replace")


def http_slow_loris(host: str, port: int, hold_s: float,
                    timeout_s: float = 30.0) -> str:
    """Dribble a partial request line, then stall for ``hold_s``. A
    hardened server times the read out (408) or closes; returns the
    status line ('' for a silent close). Never wedges the pump — the
    read happens on the event loop, not the engine thread."""
    with _connect(host, port, timeout_s) as s:
        s.sendall(b"POST /v1/gen")          # incomplete request line
        deadline = hold_s
        try:
            s.settimeout(deadline + timeout_s)
            head = s.recv(4096)             # server acts first: 408/close
        except (socket.timeout, OSError):
            return ""
    return head.split(b"\r\n", 1)[0].decode("latin-1", "replace")


def http_disconnect_mid_stream(host: str, port: int, body: dict,
                               after_tokens: int = 1,
                               timeout_s: float = 60.0) -> int:
    """POST /v1/generate, read until ``after_tokens`` ``event: token``
    frames arrived, then vanish (abortive close — RST, not FIN — so the
    server sees a reset on its next write). Returns tokens seen."""
    raw = _post_bytes("/v1/generate", json.dumps(body).encode())
    s = _connect(host, port, timeout_s)
    try:
        s.sendall(raw)
        seen, buf = 0, b""
        while seen < after_tokens:
            chunk = s.recv(4096)
            if not chunk:
                break
            buf += chunk
            seen = buf.count(b"event: token")
        # SO_LINGER(0): close sends RST immediately, the bluntest
        # disconnect a client can produce
        s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                     struct.pack("ii", 1, 0))
        return seen
    finally:
        s.close()
