"""Continuous-batching serving engine over HQP artifacts.

The ``Engine`` owns a slot-based batch of ``n_slots`` concurrent requests.
Requests are admitted into free slots on arrival, prefilled in chunks
interleaved with batched decode steps (``serving.scheduler`` owns the
policy), and evicted on EOS / length — freeing the slot for the next waiting
request. All device work goes through a fixed set of jitted callables with
a **static slot count**:

  _reset_fn  (pool, slot, template)          admission: zero one slot
  _prefill_fn(params, pool, slot, chunk, window)
                                             one prompt chunk into one slot
  _decode_fn (params, pool, tokens, active, eos, budget, window)
                                             ``decode_steps`` batched steps
                                             entirely on device (lax.scan)
  _spec_prefill_fn / SpecDecoder.spec_fn     the speculative mode's fused
                                             dual-pool prefill and
                                             draft->verify cycles (§11)

so steady-state serving never retraces (prefill compiles once per distinct
(chunk length, window bucket); decode once per window bucket). The state
pool is built on ``init_decode_state(..., params=...)``: HQP-compacted
artifacts size their own caches, and ``QuantizedLinear`` weights dispatch
through the kernels/backend registry exactly as on the serial path.

Two length-aware fast paths (DESIGN.md §10):

  * every KV attend carries a STATIC ``window`` — the live sequence bound
    bucketed to ``SchedulerConfig.window_block`` — so decode/prefill traffic
    scales with actual sequence length, not cache capacity;
  * decode runs ``SchedulerConfig.decode_steps`` greedy steps per dispatch
    inside a jitted ``lax.scan``: on-device argmax, token feedback, and
    per-slot EOS/length stop flags (stopped slots are select-masked frozen),
    with ONE host sync per scan to harvest the emitted tokens — not one per
    token (``stats["host_syncs"]`` vs ``stats["device_steps"]`` makes the
    ratio observable).

Token-identity contract: engine outputs are bit-identical to serial
single-request decode because (a) every per-slot computation is independent
across the batch axis, (b) chunked prefill and decode attend the cache
through the SAME backend primitives the serial path resolves to
(``prefill_attention`` / ``decode_attention``), whose causal limits are
absolute positions — so chunk boundaries, query-tile sizes, and window
buckets all yield bit-identical logits (out-of-window/limit positions
contribute exact zeros) — and (c) inactive/stopped slots are select-masked
back to their pre-step state after every batched decode step, on device.

Beyond greedy lockstep, the engine carries two optional modes (both
preserving the identity contract in their greedy forms): seeded
temperature/top-k sampling (``serving.sampling`` — keys derive from seed x
absolute position, so engine and serial draws coincide) and SELF-
SPECULATIVE decoding (``serving.speculative``, DESIGN.md §11 — the HQP
artifact drafts ``spec_k`` tokens per cycle over its own compacted pool,
the bf16 parent verifies all of them in one ``prefill``-route pass, and
greedy output stays bit-identical to serial bf16 decode).

``REPRO_DEBUG_WINDOW=1`` arms a host-side assert in ``step()`` that catches
an undersized static window (< start + Sq) before dispatch — without it a
miscomputed window silently truncates the visible cache and produces wrong
tokens with no error.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.analysis.invariants import declare_invariants
from repro.kernels.kv_layout import page_count
from repro.models import lm
from repro.serving import sampling as smp
from repro.serving import state_pool as sp
from repro.serving.scheduler import (DECODE, PREFILL, Scheduler,
                                     SchedulerConfig)
from repro.serving.speculative import SpecDecoder
from repro.sharding.ctx import RunContext, default_ctx

FREE = "free"


@dataclasses.dataclass
class Request:
    """One generation request (token ids in, token ids out; greedy).

    ``uid`` is engine-assigned at submit() (the return value); any value set
    by the caller is ignored for identity."""
    prompt: Sequence[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    uid: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    uid: int
    prompt_len: int
    tokens: List[int]                 # generated ids (EOS included if hit)
    finish_reason: str                # "eos" | "length"
    t_submit: float
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_finish - self.t_submit


@dataclasses.dataclass
class _Slot:
    idx: int
    stage: str = FREE                 # free | prefill | decode
    prompt: Optional[np.ndarray] = None
    prefill_done: int = 0
    last_token: int = 0
    prev_token: int = 0               # token at pos-1 (speculative healing
                                      # chunk re-feeds [prev, last])
    result: Optional[RequestResult] = None
    eos_id: Optional[int] = None
    max_new_tokens: int = 0
    pages: List[int] = dataclasses.field(default_factory=list)
    n_shared: int = 0                 # leading pages also referenced by the
                                      # prefix cache / other slots: written
                                      # only after copy-on-write


def _kv_bytes(pool) -> int:
    """Total device bytes of the position-indexed KV entries of a pool
    (recurrent state excluded) — the quantity paging exists to shrink."""
    return sum(leaf.nbytes
               for entry in pool["caches"] if sp.is_kv_entry(entry)
               for leaf in jax.tree_util.tree_leaves(entry))


def _pick_token(logits_row, pos: int, sampler) -> int:
    """Host-side token pick shared by every single-row emission surface
    (engine prefill tails, serial_decode). ``sampler=None`` is greedy:
    host ``np.argmax``, the pre-sampling bitwise path. A non-None sampler
    is the jitted position-keyed draw — ``pos`` is the absolute position
    the token's KV will be written at, the key-derivation rule every
    sampling surface shares."""
    if sampler is None:
        return int(np.argmax(np.asarray(logits_row)))
    return int(sampler(logits_row, jnp.int32(pos)))


class Engine:
    """Continuous-batching engine serving a (possibly HQP-quantized) LM."""

    def __init__(self, params: Any, cfg, ctx: Optional[RunContext] = None,
                 n_slots: int = 4, max_seq: int = 128,
                 sched: Optional[SchedulerConfig] = None,
                 sampling: Optional[smp.SamplingConfig] = None,
                 draft_params: Any = None, spec_k: int = 4,
                 spec_cycles: int = 1,
                 draft_ctx: Optional[RunContext] = None,
                 draft_manifest=None, page_size: Optional[int] = None,
                 total_pages: Optional[int] = None,
                 prefix_cache: bool = True,
                 clock=telemetry.default_clock):
        """``sampling``: temperature/top-k/seeded sampling for every decode
        surface (None = greedy, the bit-identical-to-serial default).

        ``clock``: the injectable monotonic clock behind every timestamp
        the engine takes — request lifecycle times, per-step phase
        attribution (``last_step``), and span recording. The service
        layer re-points it at its own clock on attach so one fake clock
        drives the whole plane in tests.

        ``draft_params`` switches on SPECULATIVE mode: ``params`` becomes
        the verifier (bf16 parent), ``draft_params`` the drafter (the HQP
        artifact), and each decode dispatch runs ``spec_cycles`` speculative
        cycles — ``spec_k`` draft steps + one multi-position verify each —
        instead of ``decode_steps`` verifier steps. ``draft_ctx`` sizes the
        drafter's
        own pool (INT8 KV for an artifact drafter); ``draft_manifest``
        (the artifact's ``HQPManifest``) is checked for vocab/arch
        compatibility before any device work.

        ``page_size`` switches on PAGED KV (DESIGN.md §12): the per-slot KV
        pool becomes a global arena of ``total_pages`` fixed-size pages
        (default: full provisioning, ``1 + n_slots *
        ceil(max_seq/page_size)`` — one extra for the trash page) with a
        host-side free-list allocator and per-slot page tables. Pages are
        allocated covering the prompt at admission and grown on demand
        before each decode dispatch; ``prefix_cache=True`` additionally
        keys completed page-aligned prompt heads by content hash so a
        repeated prompt head maps the cached pages copy-free and prefills
        only its tail. ``page_size == max_seq`` is the contiguous-identity
        degenerate case (one page per slot). Outputs stay token-identical
        to the contiguous pool at every page size."""
        if cfg.frontend.kind != "none":
            raise NotImplementedError(
                "Engine v1 serves token-only archs; frontend (VLM/audio) "
                "requests need per-slot embed plumbing — a later PR")
        self.params = params
        self.cfg = cfg
        self.ctx = ctx or default_ctx()
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.scheduler = Scheduler(sched)
        self.sampling = sampling or smp.GREEDY
        self.paged = page_size is not None
        self.page_size = page_size if self.paged else max_seq
        if self.paged:
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            self.max_pages = page_count(max_seq, page_size)
            if total_pages is None:
                total_pages = 1 + n_slots * self.max_pages
            self.total_pages = total_pages
            self.alloc = sp.PageAllocator(total_pages)
            self.prefix = (sp.PrefixCache(self.alloc, page_size)
                           if prefix_cache else None)
            # host mirror of every slot's page table; device copies are
            # cached per (state, active mask) in ``_dispatch_table`` (rows
            # of inactive slots redirected to the trash page)
            self.table = np.zeros((n_slots, self.max_pages), np.int32)
            self.pool = sp.init_paged_pool(cfg, n_slots, max_seq, self.ctx,
                                           params=params,
                                           page_size=page_size,
                                           total_pages=total_pages)
        else:
            self.alloc = None
            self.prefix = None
            self.pool = sp.init_pool(cfg, n_slots, max_seq, self.ctx,
                                     params=params)
            # contiguous dispatches still feed the (ignored) table operand
            # so both modes share one set of jitted callables
            self.table = np.zeros((n_slots, 1), np.int32)
        self._table_cache: dict = {}    # device tables, see _dispatch_table
        self._template = sp.init_slot_template(cfg, max_seq, self.ctx,
                                               params=params)
        self.spec: Optional[SpecDecoder] = None
        if draft_params is not None:
            self.spec = SpecDecoder(cfg, draft_params, params, ctx=self.ctx,
                                    draft_ctx=draft_ctx, k=spec_k,
                                    cycles=spec_cycles,
                                    sampling=self.sampling,
                                    draft_manifest=draft_manifest,
                                    paged=self.paged)
            dctx = self.spec.draft_ctx
            if self.paged:
                # ONE allocator + table addresses both arenas: the pools'
                # positions stay aligned, so page p holds the same token
                # span in the drafter and verifier arenas
                self.draft_pool = sp.init_paged_pool(
                    cfg, n_slots, max_seq, dctx, params=draft_params,
                    page_size=page_size, total_pages=total_pages)
            else:
                self.draft_pool = sp.init_pool(cfg, n_slots, max_seq, dctx,
                                               params=draft_params)
            self._draft_template = sp.init_slot_template(cfg, max_seq, dctx,
                                                         params=draft_params)
        kv_bytes = _kv_bytes(self.pool) + (
            _kv_bytes(self.draft_pool) if self.spec is not None else 0)
        if self.paged:
            self._kv_page_bytes = kv_bytes // total_pages
            self._kv_token_bytes = self._kv_page_bytes // self.page_size
        else:
            self._kv_token_bytes = kv_bytes // (n_slots * max_seq)
        self.slots = [_Slot(i) for i in range(n_slots)]
        self.waiting: List[Request] = []
        self._uid = itertools.count()
        self.ticks = 0
        self.clock = clock
        # optional telemetry.SpanRecorder — a passive sink fed engine
        # timestamps; None costs nothing on the hot path
        self.tracer: Optional[telemetry.SpanRecorder] = None
        # per-step measurement surface: {"wall_s", "phases",
        # "prefill_tokens", "decode_tokens"} — the service feeds the
        # admission EWMA and the phase histograms from this instead of
        # re-measuring around step()
        self.last_step: Optional[dict] = None
        self._ph: Dict[str, float] = {}
        # optional per-token sink (the service layer's streaming hook):
        # called as on_token(uid, token) from _emit for EVERY emitted token,
        # before finish bookkeeping — so a streaming front door sees tokens
        # at host-sync granularity instead of waiting for the full result
        self.on_token = None
        # drafted_tokens counts every candidate the device produced for a
        # slot that was live at dispatch (speculative drafts, or plain-mode
        # scan steps — including steps burned on slots that froze mid-scan,
        # the device work the old stats under-counted); accepted_tokens
        # counts the candidates that became emitted request tokens
        # (speculative corrections are emitted but NOT accepted drafts), so
        # acceptance rate = accepted_tokens / drafted_tokens from stats
        # alone, in both modes.
        self.stats = {"prefill_ticks": 0, "decode_ticks": 0,
                      "decode_slot_steps": 0, "prefill_tokens": 0,
                      "host_syncs": 0, "device_steps": 0,
                      "drafted_tokens": 0, "accepted_tokens": 0,
                      "prefix_hits": 0, "prefix_hit_tokens": 0,
                      "bytes_saved": 0, "cow_copies": 0,
                      "pages_in_use": 0, "pages_peak": 0,
                      "cancelled": 0, "faults": 0,
                      "kv_bytes_peak": 0 if self.paged else kv_bytes}
        # fault attribution for request-scoped isolation (see step):
        # ("admit", request) | ("slots", [idx, ...]) | None, set just
        # before each fallible phase so _absorb_fault knows the blast
        # radius of whatever raised
        self._fault_phase = None

        cfg_, ctx_ = self.cfg, self.ctx
        paged = self.paged
        scfg, base_key = self.sampling, smp.base_key(self.sampling)
        decode_steps = self.scheduler.cfg.decode_steps

        def _row(table, slot):
            # one compiled executable serves every slot: the slot's table
            # row is sliced with a traced index
            return jax.lax.dynamic_slice(table, (slot, 0),
                                         (1, table.shape[1]))

        def _reset(pool, slot, template, pos0):
            return sp.reset_slot(pool, slot, template, pos0, paged)

        def _prefill(params, pool, table, slot, chunk, window):
            st = sp.gather_slot(pool, slot, paged)
            if paged:
                st = dict(st, pages=_row(table, slot))
            # route="prefill": every chunk — the 1-token tail included —
            # takes the backend prefill_attention primitive, the same
            # primitive serial whole-prompt prefill resolves to, so chunked
            # and whole-prompt prefill share bit-identical numerics on
            # every backend (the route enum makes the old fragile
            # "tail chunk must pass decode=False" contract unexpressible)
            logits, new = lm.decode_step(params, cfg_, st, chunk, ctx_,
                                         window=window, route="prefill")
            return logits[:, -1], sp.scatter_slot(pool, slot, new, paged)

        def _spec_prefill(dparams, vparams, dpool, vpool, table, slot,
                          chunk, window):
            # speculative mode prefills BOTH pools from one dispatch (the
            # drafter's chunk logits are never consumed — the first token
            # always comes from the verifier); fusing halves the per-chunk
            # dispatch overhead vs two _prefill_fn calls
            pg = (dict(pages=_row(table, slot)) if paged else {})
            vst = dict(sp.gather_slot(vpool, slot, paged), **pg)
            vlogits, vnew = lm.decode_step(vparams, cfg_, vst, chunk, ctx_,
                                           window=window, route="prefill")
            dst = dict(sp.gather_slot(dpool, slot, paged), **pg)
            _, dnew = lm.decode_step(dparams, cfg_, dst, chunk, ctx_,
                                     window=window, route="prefill")
            return (vlogits[:, -1],
                    sp.scatter_slot(dpool, slot, dnew, paged),
                    sp.scatter_slot(vpool, slot, vnew, paged))

        def _decode(params, pool, table, tokens, active, eos, budget,
                    window):
            """``decode_steps`` greedy steps on device. tokens (B, 1) i32 =
            each live slot's last emitted token; active (B,) bool; eos (B,)
            i32 (-1 = no EOS id); budget (B,) i32 = tokens the slot may
            still emit. Returns (toks (K, B), emitted (K, B) bool, pool):
            ``emitted[t, i]`` marks a real token — slots that hit EOS or
            exhaust their budget mid-scan are frozen (select-masked) for the
            remaining steps, exactly as the host's eviction logic would."""
            def body(carry, _):
                pool, tok, live, left = carry
                st = dict(pool, pages=table) if paged else pool
                logits, new = lm.decode_step(params, cfg_, st, tok, ctx_,
                                             window=window, route="decode")
                # per-slot key derives from the sampled token's absolute
                # position (new pos), never slot/tick — so engine sampling
                # reproduces serial sampling token-for-token per seed;
                # greedy is a static argmax branch (no keys, bit-identical
                # to the pre-sampling engine)
                nxt = smp.sample_batch(logits[:, -1], scfg, base_key,
                                       new["pos"])
                pool = sp.select_slots(new, pool, live, paged)
                left = jnp.where(live, left - 1, left)
                stop = ((eos >= 0) & (nxt == eos)) | (left <= 0)
                return ((pool, jnp.where(live, nxt, tok[:, 0])[:, None],
                         live & ~stop, left),
                        (jnp.where(live, nxt, 0), live))

            (pool, _, _, _), (toks, emitted) = jax.lax.scan(
                body, (pool, tokens, active, budget), None,
                length=decode_steps)
            return toks, emitted, pool

        def _copy_page(pool, dpool, src, dst):
            # copy-on-write: duplicate arena page src -> dst in every KV
            # entry of both pools (dpool is None outside speculative mode;
            # the page axis of an arena leaf is axis 1, under the group
            # stack)
            def cp(leaf):
                page = jax.lax.dynamic_slice_in_dim(leaf, src, 1, 1)
                return jax.lax.dynamic_update_slice_in_dim(leaf, page,
                                                           dst, 1)
            def one(pool):
                caches = tuple(
                    jax.tree.map(cp, e) if sp.is_kv_entry(e) else e
                    for e in pool["caches"])
                return {"caches": caches, "pos": pool["pos"]}
            return one(pool), (None if dpool is None else one(dpool))

        # every hot path declares its compiled-artifact invariants next to
        # its jit (DESIGN.md §15): scripts/check_static.py lowers these with
        # representative shapes and walks the optimized HLO to enforce the
        # claims. n_windows is the window-bucketing retrace bound: static
        # windows are window_block multiples, so steady-state serving
        # compiles at most max_seq/window_block decode variants (prefill
        # additionally varies over the <= prefill_chunk tail-chunk widths).
        n_windows = -(-max_seq // self.scheduler.cfg.window_block)
        n_chunks = self.scheduler.cfg.prefill_chunk
        self._reset_fn = declare_invariants(
            "engine.reset", host_syncs=1, donated=("pool",),
            forbid_f32_roundtrip_on=("kv",),
            max_lowerings=2 if self.spec is not None else 1,
        )(jax.jit(_reset, donate_argnums=(0,)))
        self._prefill_fn = declare_invariants(
            "engine.prefill", host_syncs=1, donated=("pool",),
            forbid_f32_roundtrip_on=("kv",),
            max_lowerings=n_windows * n_chunks, static_argnums=(5,),
        )(jax.jit(_prefill, donate_argnums=(1,), static_argnums=(5,)))
        self._decode_fn = declare_invariants(
            "engine.decode", host_syncs=1, donated=("pool",),
            forbid_f32_roundtrip_on=("kv",),
            max_lowerings=n_windows, static_argnums=(7,),
        )(jax.jit(_decode, donate_argnums=(1,), static_argnums=(7,)))
        self._spec_prefill_fn = declare_invariants(
            "engine.spec_prefill", host_syncs=1, donated=("dpool", "vpool"),
            forbid_f32_roundtrip_on=("kv",),
            max_lowerings=n_windows * n_chunks, static_argnums=(7,),
        )(jax.jit(_spec_prefill, donate_argnums=(2, 3), static_argnums=(7,)))
        self._copy_page_fn = declare_invariants(
            "engine.copy_page", host_syncs=1, donated=("pool", "dpool"),
            forbid_f32_roundtrip_on=("kv",),
        )(jax.jit(_copy_page, donate_argnums=(0, 1)))
        self._sample_fn = jax.jit(lambda lg, p: smp.sample(
            lg, scfg, smp.token_key(base_key, p)))

    def _first_token(self, logits_row, pos: int) -> int:
        """Token emitted from a prefill tail chunk's last-position logits.
        ``pos`` is the prompt length; see ``_pick_token`` for the key rule."""
        return _pick_token(logits_row, pos,
                           None if self.sampling.is_greedy
                           else self._sample_fn)

    # ------------------------------------------------------------ paged KV
    def _note_pages(self) -> None:
        n = self.alloc.pages_in_use
        self.stats["pages_in_use"] = n
        if n > self.stats["pages_peak"]:
            self.stats["pages_peak"] = n
            self.stats["kv_bytes_peak"] = n * self._kv_page_bytes

    def _alloc_pages(self, n: int) -> List[int]:
        """Allocate n pages, evicting prefix-cache LRU entries under arena
        pressure; raises MemoryError only once the cache is drained."""
        if n <= 0:
            return []
        while True:
            try:
                return self.alloc.alloc(n)
            except MemoryError:
                if self.prefix is None or not self.prefix.evict_lru():
                    raise

    def _map_slot_pages(self, slot: _Slot, prompt: np.ndarray) -> int:
        """Admission: map the slot's page-table row for ``prompt`` — the
        longest page-aligned prefix-cache hit (copy-free, refcounted) plus
        fresh pages for the rest of the prompt. Returns the hit length in
        tokens (the position prefill resumes from)."""
        hit, pages = ((0, []) if self.prefix is None
                      else self.prefix.lookup(prompt))
        try:
            pages = pages + self._alloc_pages(
                page_count(prompt.size, self.page_size) - len(pages))
        except MemoryError:
            # lookup() ref'd the hit pages for this slot; the mapping
            # failed, so drop those references or they leak forever
            if pages:
                self.alloc.unref(pages)
            raise
        slot.pages = pages
        slot.n_shared = hit // self.page_size
        self.table[slot.idx] = 0
        self.table[slot.idx, :len(pages)] = pages
        self._table_cache.clear()
        if hit:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += hit
            self.stats["bytes_saved"] += hit * self._kv_token_bytes
        self._note_pages()
        return hit

    def _ensure_capacity(self, slot: _Slot, upto: int) -> None:
        """Grow the slot's table to cover writes at positions < ``upto``
        BEFORE the dispatch: a write through an unmapped (zero) table entry
        would land on the trash page and silently lose that KV."""
        need = page_count(min(upto, self.max_seq), self.page_size)
        if need > len(slot.pages):
            new = self._alloc_pages(need - len(slot.pages))
            self.table[slot.idx, len(slot.pages):need] = new
            slot.pages.extend(new)
            self._table_cache.clear()
            self._note_pages()

    def _ensure_writable(self, slot: _Slot, pos: int) -> None:
        """Copy-on-write ahead of a dispatch whose first KV write lands at
        ``pos``: if that position sits inside the slot's shared-page range
        (only the speculative healing chunk — writing at pos-1 — can reach
        it, when the prompt length is page-aligned and its last page went
        into the prefix cache), the page is duplicated and the table
        repointed so sharers never observe the write."""
        if pos < 0 or pos >= slot.n_shared * self.page_size:
            return
        idx = pos // self.page_size        # == n_shared - 1: writes only
        old = slot.pages[idx]              # ever touch the LAST shared page
        if self.alloc.refs[old] > 1:
            new = self._alloc_pages(1)[0]
            self.pool, dpool = self._copy_page_fn(
                self.pool,
                self.draft_pool if self.spec is not None else None,
                jnp.int32(old), jnp.int32(new))
            if dpool is not None:
                self.draft_pool = dpool
            self.alloc.unref([old])
            slot.pages[idx] = new
            self.table[slot.idx, idx] = new
            self._table_cache.clear()
            self.stats["cow_copies"] += 1
        slot.n_shared = idx                # earlier pages are never written
        self._note_pages()

    def _release_slot_pages(self, slot: _Slot) -> None:
        """Eviction: drop the slot's page references (pages the prefix
        cache also holds stay resident for future hits) and zero its table
        row."""
        if slot.pages:
            self.alloc.unref(slot.pages)
            slot.pages = []
            slot.n_shared = 0
            self.table[slot.idx] = 0
            self._table_cache.clear()
            self._note_pages()

    def _dispatch_table(self, active: Optional[np.ndarray] = None):
        """Device copy of the page table for one dispatch. Batched decode
        dispatches pass ``active`` to redirect every inactive slot's row to
        the trash page: the shared arena cannot be select-masked per slot,
        so inactive rows' garbage writes are steered to the reserved page
        instead (their live pages are never addressed at all).

        The device copy is cached per (table state, active mask): the table
        only mutates on admit / growth / CoW / eviction, so steady-state
        decode ticks reuse one resident array instead of paying an H2D
        upload per dispatch (none of the jitted callables donate the table
        argument, so the cached buffer stays live)."""
        key = (active.tobytes()
               if active is not None and self.paged else None)
        dev = self._table_cache.get(key)
        if dev is None:
            tab = self.table
            if key is not None:
                tab = np.where(active[:, None], tab, 0)
            dev = self._table_cache[key] = jnp.asarray(tab)
        return dev

    def _window(self, needed: int) -> int:
        if self.paged:
            return self.scheduler.visible_window(
                needed, self.max_seq, page_multiple=self.page_size)
        return self.scheduler.visible_window(needed, self.max_seq)

    # ------------------------------------------------------------- lifecycle
    def submit(self, request: Request) -> int:
        prompt = np.asarray(request.prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token list")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the first token "
                             "falls out of prefill unconditionally)")
        if prompt.size + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_seq={self.max_seq}")
        # identity is always engine-assigned: a caller-supplied Request.uid
        # could collide with the internal counter and alias two requests
        uid = next(self._uid)
        req = dataclasses.replace(request, uid=uid, prompt=prompt)
        req._t_submit = self.clock()       # type: ignore[attr-defined]
        if self.tracer is not None:
            self.tracer.submit(uid, req._t_submit, int(prompt.size))
        self.waiting.append(req)
        return uid

    def cancel(self, uid: int) -> bool:
        """Abort a request wherever it currently lives (the service layer's
        deadline-eviction hook). A queued request is dropped from the waiting
        list; an in-flight one — mid-prefill included — has its slot freed
        immediately and, in paged mode, its page references released (pages
        the prefix cache also holds stay resident for future hits). The
        slot's device state needs no scrubbing: a freed slot's stale KV is
        masked by ``pos`` on the next admission, exactly as on normal
        eviction. Returns False when the uid is unknown or already
        finished."""
        for i, req in enumerate(self.waiting):
            if req.uid == uid:
                del self.waiting[i]
                self.stats["cancelled"] += 1
                if self.tracer is not None:
                    self.tracer.finish(uid, self.clock(), "cancelled")
                return True
        for slot in self.slots:
            if slot.stage != FREE and slot.result is not None \
                    and slot.result.uid == uid:
                if self.tracer is not None:
                    self.tracer.finish(uid, self.clock(), "cancelled",
                                       n_tokens=len(slot.result.tokens),
                                       pages_held=len(slot.pages))
                slot.stage = FREE
                slot.result = None
                slot.prompt = None
                if self.paged:
                    self._release_slot_pages(slot)
                self.stats["cancelled"] += 1
                return True
        return False

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s.stage != FREE for s in self.slots)

    @property
    def n_active(self) -> int:
        return sum(s.stage != FREE for s in self.slots)

    def _admit(self) -> None:
        for slot in self.slots:
            if not self.waiting:
                return
            if slot.stage != FREE:
                continue
            req = self.waiting.pop(0)
            self._fault_phase = ("admit", req)
            pos0 = (self._map_slot_pages(slot, req.prompt) if self.paged
                    else 0)
            self.pool = self._reset_fn(self.pool, jnp.int32(slot.idx),
                                       self._template, jnp.int32(pos0))
            if self.spec is not None:
                self.draft_pool = self._reset_fn(
                    self.draft_pool, jnp.int32(slot.idx),
                    self._draft_template, jnp.int32(pos0))
            slot.stage = PREFILL
            slot.prompt = req.prompt
            slot.prefill_done = pos0
            slot.eos_id = req.eos_id
            slot.max_new_tokens = req.max_new_tokens
            t_admit = self.clock()
            slot.result = RequestResult(
                uid=req.uid, prompt_len=int(req.prompt.size), tokens=[],
                finish_reason="", t_submit=req._t_submit,
                t_admit=t_admit)
            if self.tracer is not None:
                self.tracer.admit(req.uid, t_admit, slot.idx)
            self._fault_phase = None

    def _emit(self, slot: _Slot, tok: int,
              finished: List[RequestResult]) -> None:
        res = slot.result
        if not res.tokens:
            res.t_first_token = self.clock()
            if self.tracer is not None:
                self.tracer.first_token(res.uid, res.t_first_token)
        res.tokens.append(tok)
        if self.on_token is not None:
            self.on_token(res.uid, tok)
        done_eos = slot.eos_id is not None and tok == slot.eos_id
        done_len = len(res.tokens) >= slot.max_new_tokens
        if done_eos or done_len:
            res.finish_reason = "eos" if done_eos else "length"
            res.t_finish = self.clock()
            if self.tracer is not None:
                self.tracer.finish(res.uid, res.t_finish, res.finish_reason,
                                   n_tokens=len(res.tokens),
                                   pages_held=len(slot.pages))
            finished.append(res)
            slot.stage = FREE          # eviction: slot reusable next tick
            slot.result = None
            slot.prompt = None
            if self.paged:
                self._release_slot_pages(slot)
        else:
            slot.last_token = tok
            slot.stage = DECODE

    # ------------------------------------------------------------------ step
    def _slot_pos(self, slot: _Slot) -> int:
        """Cache position the slot's next decode step writes at (the engine's
        host-side mirror of ``pool["pos"][slot.idx]``): the whole prompt plus
        every emitted token except the newest (whose KV isn't written yet)."""
        return int(slot.prompt.size) + len(slot.result.tokens) - 1

    def _debug_check_window(self, window: int, required: int,
                            kind: str) -> None:
        """Opt-in (``REPRO_DEBUG_WINDOW=1``) host-side guard on the static
        visible window, run before dispatch. An undersized window —
        ``window < start + Sq`` for a consumed row — does NOT error on
        device: the attend silently truncates the visible cache and the
        engine emits wrong tokens. This assert turns that silent corruption
        into an immediate host error; it is opt-in because it runs on every
        dispatch in the hot loop."""
        if os.environ.get("REPRO_DEBUG_WINDOW") != "1":
            return
        if window < min(required, self.max_seq):
            raise AssertionError(
                f"undersized visible window on {kind} dispatch: window="
                f"{window} < required={min(required, self.max_seq)} — the "
                f"attend would silently truncate the cache and emit wrong "
                f"tokens (scheduler.visible_window miscomputed?)")

    def step(self) -> List[RequestResult]:
        """One engine tick: admit, then run one scheduler action (a decode
        action runs ``decode_steps`` device steps). Returns requests that
        finished this tick.

        REQUEST-SCOPED FAULT ISOLATION: an exception inside the tick is
        absorbed — the requests the failing phase was working on (the
        admission's request; the prefill slot; a decode dispatch's batch)
        finish with ``finish_reason="error"``, their slots and pages are
        freed, ``stats["faults"]`` counts them, and the engine keeps
        serving everything else.  Two kinds propagate to the caller
        instead: ``AssertionError`` (invariant checks like the
        REPRO_DEBUG_WINDOW guard or allocator refcount asserts — those
        are engine bugs, and blaming the request they happened to fire
        on would hide them), and any fault the engine cannot attribute
        to requests (``_fault_phase`` unset).

        Every step leaves its measurement behind in ``last_step``:
        wall time, the per-phase breakdown (admit / prefill dispatch /
        decode scan / host sync / token fanout), and the step's
        prefill/decode token deltas — the single source the service
        layer feeds to both the admission EWMA and the phase
        histograms."""
        self._fault_phase = None
        self._ph = {}
        p0 = self.stats["prefill_tokens"]
        a0 = self.stats["accepted_tokens"]
        t0 = self.clock()
        try:
            out = self._step_inner()
        except AssertionError:
            raise
        except Exception:
            out = self._absorb_fault()
        wall = self.clock() - t0
        self._ph["total"] = wall
        self.last_step = {
            "wall_s": wall,
            "phases": self._ph,
            "prefill_tokens": self.stats["prefill_tokens"] - p0,
            "decode_tokens": self.stats["accepted_tokens"] - a0,
        }
        if self.tracer is not None:
            self.tracer.span("step", None, t0, t0 + wall,
                             **{k: round(v, 9)
                                for k, v in self._ph.items()})
        return out

    def _step_inner(self) -> List[RequestResult]:
        clk = self.clock
        ph = self._ph
        t_in = clk()
        self._admit()
        ph["admit"] = clk() - t_in
        prefilling = [s.idx for s in self.slots if s.stage == PREFILL]
        decoding = [s.idx for s in self.slots if s.stage == DECODE]
        action = self.scheduler.next_action(prefilling, decoding)
        finished: List[RequestResult] = []

        if action.kind == PREFILL:
            slot = self.slots[action.slot]
            uid = slot.result.uid
            self._fault_phase = ("slots", [action.slot])
            lo, hi = self.scheduler.chunk_bounds(slot.prompt.size,
                                                 slot.prefill_done)
            chunk = jnp.asarray(slot.prompt[None, lo:hi])
            window = self._window(hi)
            # the chunk's last query sits at absolute position hi-1
            self._debug_check_window(window, hi, "prefill")
            table = self._dispatch_table()
            t_d0 = clk()
            if self.spec is not None:
                last_logits, self.draft_pool, self.pool = \
                    self._spec_prefill_fn(
                        self.spec.draft_params, self.params, self.draft_pool,
                        self.pool, table, jnp.int32(slot.idx), chunk, window)
            else:
                last_logits, self.pool = self._prefill_fn(
                    self.params, self.pool, table, jnp.int32(slot.idx),
                    chunk, window)
            t_d1 = clk()
            ph["prefill_dispatch"] = t_d1 - t_d0
            slot.prefill_done = hi
            self.stats["prefill_ticks"] += 1
            self.stats["prefill_tokens"] += hi - lo
            emitted_tail = 0
            if hi == slot.prompt.size:
                if self.paged and self.prefix is not None:
                    # the prompt's KV is complete: register every page-
                    # aligned prefix for future admissions. The slot's own
                    # pages up to the inserted length are now shared —
                    # future in-place writes there must copy first.
                    ins = self.prefix.insert(slot.prompt, slot.pages, hi)
                    slot.n_shared = max(slot.n_shared,
                                        ins // self.page_size)
                    self._note_pages()
                tok = self._first_token(last_logits[0], hi)
                t_s1 = clk()
                ph["host_sync"] = t_s1 - t_d1
                self.stats["host_syncs"] += 1
                # the speculative healing chunk re-feeds [prev, last]: after
                # prefill, pos-1 holds the last prompt token
                slot.prev_token = int(slot.prompt[-1])
                emitted_tail = 1
                # span before the tail _emit: a max_new_tokens=1 request
                # finishes inside it, and its finish instant must account
                # for this chunk's token
                if self.tracer is not None:
                    self.tracer.span("prefill", uid, t_d0, t_s1,
                                     lo=lo, hi=hi, tokens=emitted_tail)
                self._emit(slot, tok, finished)
                ph["token_fanout"] = clk() - t_s1
            elif self.tracer is not None:
                self.tracer.span("prefill", uid, t_d0, clk(),
                                 lo=lo, hi=hi, tokens=emitted_tail)
        elif action.kind == DECODE and self.spec is not None:
            finished = self._spec_decode(action, finished)
        elif action.kind == DECODE:
            k_steps = self.scheduler.cfg.decode_steps
            t_d0 = clk()
            tokens = np.zeros((self.n_slots, 1), np.int32)
            active = np.zeros((self.n_slots,), bool)
            eos = np.full((self.n_slots,), -1, np.int32)
            budget = np.ones((self.n_slots,), np.int32)
            for i in action.slots:
                slot = self.slots[i]
                # capacity growth can exhaust the arena — blame only the
                # slot being grown, not the whole dispatch batch
                self._fault_phase = ("slots", [i])
                tokens[i, 0] = slot.last_token
                active[i] = True
                if slot.eos_id is not None:
                    eos[i] = slot.eos_id
                budget[i] = slot.max_new_tokens - len(slot.result.tokens)
                if self.paged:
                    # deepest write this dispatch: pos + live steps (frozen
                    # slots rewrite their freeze position, already covered)
                    self._ensure_capacity(
                        slot, min(self._slot_pos(slot) + k_steps,
                                  int(slot.prompt.size)
                                  + slot.max_new_tokens))
            # past here a fault hits the batched dispatch itself: every
            # slot in the action is the blast radius
            self._fault_phase = ("slots", list(action.slots))
            # the deepest live slot after k_steps attends positions
            # <= max(pos) + k_steps - 1  ->  window covers max(pos) + k_steps
            needed = max(self._slot_pos(self.slots[i])
                         for i in action.slots) + k_steps
            window = self._window(needed)
            self._debug_check_window(window, needed, "decode")
            toks, emitted, self.pool = self._decode_fn(
                self.params, self.pool, self._dispatch_table(active),
                jnp.asarray(tokens), jnp.asarray(active), jnp.asarray(eos),
                jnp.asarray(budget), window)
            t_d1 = clk()
            ph["decode_scan"] = t_d1 - t_d0
            toks, emitted = np.asarray(toks), np.asarray(emitted)
            t_s1 = clk()
            ph["host_sync"] = t_s1 - t_d1
            self.stats["host_syncs"] += 1
            self.stats["device_steps"] += k_steps
            # every slot live at dispatch burns all k_steps device steps —
            # slots that freeze mid-scan included (the previously
            # under-counted device work); emitted is what actually landed
            self.stats["drafted_tokens"] += k_steps * len(action.slots)
            self.stats["accepted_tokens"] += int(emitted.sum())
            # scan spans are recorded BEFORE fanout: _emit fires terminal
            # finish instants, and the finish must account for every token
            # its work spans carry (the trace smoke asserts this). The span
            # therefore covers dispatch..host-sync; fanout is engine-side
            # bookkeeping attributed to the step track.
            if self.tracer is not None:
                per_slot = emitted.sum(axis=0)
                for i in action.slots:
                    self.tracer.span("decode", self.slots[i].result.uid,
                                     t_d0, t_s1, tokens=int(per_slot[i]),
                                     k_steps=k_steps)
            for t in range(k_steps):
                for i in action.slots:
                    if emitted[t, i]:
                        self._emit(self.slots[i], int(toks[t, i]), finished)
            t_f1 = clk()
            ph["token_fanout"] = t_f1 - t_s1
            self.stats["decode_ticks"] += 1
            self.stats["decode_slot_steps"] += int(emitted.sum())

        self.ticks += 1
        return finished

    # ------------------------------------------------------- fault isolation
    def _fail_slot(self, slot: _Slot, finished: List[RequestResult],
                   now: float) -> None:
        """Evict a faulted slot: its result finishes with
        ``finish_reason="error"``, its pages are freed, the slot is
        immediately reusable."""
        res = slot.result
        if res is not None:
            res.finish_reason = "error"
            if not res.t_first_token:
                res.t_first_token = now
            res.t_finish = now
            if self.tracer is not None:
                self.tracer.finish(res.uid, now, "error",
                                   n_tokens=len(res.tokens),
                                   pages_held=len(slot.pages))
            finished.append(res)
        slot.stage = FREE
        slot.result = None
        slot.prompt = None
        if self.paged:
            self._release_slot_pages(slot)
        self.stats["faults"] += 1

    def _pool_deleted(self) -> bool:
        """True when a fault fired mid-execution of a donating dispatch:
        the donated input buffers are consumed but the output never
        materialized — the pool is gone and must be rebuilt."""
        leaves = jax.tree_util.tree_leaves(self.pool)
        if self.spec is not None:
            leaves += jax.tree_util.tree_leaves(self.draft_pool)
        return any(getattr(leaf, "is_deleted", lambda: False)()
                   for leaf in leaves)

    def _rebuild_pools(self) -> None:
        """Re-initialize the state pool(s) after donation consumed them.
        Every slot's KV is lost, so the caller fails all active slots
        first; cached prefix pages hold vanished KV too and must go."""
        if self.paged:
            if self.prefix is not None:
                self.prefix.clear()
            self.pool = sp.init_paged_pool(
                self.cfg, self.n_slots, self.max_seq, self.ctx,
                params=self.params, page_size=self.page_size,
                total_pages=self.total_pages)
        else:
            self.pool = sp.init_pool(self.cfg, self.n_slots, self.max_seq,
                                     self.ctx, params=self.params)
        if self.spec is not None:
            dctx = self.spec.draft_ctx
            if self.paged:
                self.draft_pool = sp.init_paged_pool(
                    self.cfg, self.n_slots, self.max_seq, dctx,
                    params=self.spec.draft_params, page_size=self.page_size,
                    total_pages=self.total_pages)
            else:
                self.draft_pool = sp.init_pool(
                    self.cfg, self.n_slots, self.max_seq, dctx,
                    params=self.spec.draft_params)
        self._table_cache.clear()

    def _absorb_fault(self) -> List[RequestResult]:
        """Exception handler for one tick (called from ``step``'s except
        block; re-raises when the fault is unattributable). Returns the
        error-finished results so the service can route them."""
        phase = self._fault_phase
        self._fault_phase = None
        if phase is None:
            raise          # no request to blame: let the caller see it
        now = self.clock()
        finished: List[RequestResult] = []
        kind, who = phase
        pool_dead = self._pool_deleted()
        if kind == "admit":
            # the request was popped from waiting but its slot never went
            # live — synthesize its error result directly
            req = who
            self.stats["faults"] += 1
            if self.tracer is not None:
                self.tracer.finish(req.uid, now, "error")
            finished.append(RequestResult(
                uid=req.uid, prompt_len=int(req.prompt.size), tokens=[],
                finish_reason="error", t_submit=req._t_submit, t_admit=now,
                t_first_token=now, t_finish=now))
        else:
            for i in who:
                if self.slots[i].stage != FREE:
                    self._fail_slot(self.slots[i], finished, now)
        if pool_dead:
            # the dispatch consumed its donated pool before dying: every
            # active slot's KV went with it — fail them all and rebuild
            for slot in self.slots:
                if slot.stage != FREE:
                    self._fail_slot(slot, finished, now)
            self._rebuild_pools()
        self.ticks += 1
        return finished

    def _spec_decode(self, action, finished: List[RequestResult]
                     ) -> List[RequestResult]:
        """``c_eff`` speculative cycles over all decoding slots — k_eff
        draft steps on the drafter pool, one multi-position verify on the
        verifier pool, on-device acceptance + rollback each — with ONE host
        sync at the end. ``SpecDecoder.plan`` caps (k, cycles) so the
        deepest slot's verify writes stay inside the cache (the vmapped KV
        scatter clamps out-of-range starts, which would corrupt valid
        history)."""
        clk = self.clock
        ph = self._ph
        t_d0 = clk()
        prev = np.zeros((self.n_slots, 1), np.int32)
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        eos = np.full((self.n_slots,), -1, np.int32)
        budget = np.ones((self.n_slots,), np.int32)
        for i in action.slots:
            slot = self.slots[i]
            prev[i, 0] = slot.prev_token
            tokens[i, 0] = slot.last_token
            active[i] = True
            if slot.eos_id is not None:
                eos[i] = slot.eos_id
            budget[i] = slot.max_new_tokens - len(slot.result.tokens)
        max_pos = max(self._slot_pos(self.slots[i]) for i in action.slots)
        k_eff, c_eff = self.spec.plan(max_pos, self.max_seq,
                                      int(budget[active].max()))
        if self.paged:
            for i in action.slots:
                slot = self.slots[i]
                self._fault_phase = ("slots", [i])
                # the healing chunk's first write lands at pos-1 — possibly
                # inside a shared page (copy-on-write); the verify tail is
                # the deepest write (plan() keeps it in-bounds)
                self._ensure_writable(slot, self._slot_pos(slot) - 1)
                self._ensure_capacity(
                    slot, self._slot_pos(slot) + c_eff * (k_eff + 1))
        self._fault_phase = ("slots", list(action.slots))
        # deepest attend: the last cycle's verify chunk tail
        needed = max_pos + c_eff * (k_eff + 1)
        window = self._window(needed)
        self._debug_check_window(window, needed, "speculative")
        toks, emitted, n_acc, n_drafted, self.draft_pool, self.pool = \
            self.spec.spec_fn(
                self.spec.draft_params, self.params, self.draft_pool,
                self.pool, self._dispatch_table(active), jnp.asarray(prev),
                jnp.asarray(tokens), jnp.asarray(active), jnp.asarray(eos),
                jnp.asarray(budget), k_eff, c_eff, window)
        t_d1 = clk()
        ph["decode_scan"] = t_d1 - t_d0
        toks, emitted = np.asarray(toks), np.asarray(emitted)
        n_acc, n_drafted = np.asarray(n_acc), np.asarray(n_drafted)
        t_s1 = clk()
        ph["host_sync"] = t_s1 - t_d1
        self.stats["host_syncs"] += 1
        # k_eff drafter invocations (healing chunk included) + 1 verify
        # per cycle
        self.stats["device_steps"] += c_eff * (k_eff + 1)
        self.stats["drafted_tokens"] += int(n_drafted.sum())
        self.stats["accepted_tokens"] += int(n_acc.sum())
        # span before fanout: _emit fires terminal finish instants, and the
        # finish must account for every token its work spans carry (span
        # covers dispatch..host-sync; fanout is the step track's phase)
        if self.tracer is not None:
            per_slot = emitted.sum(axis=0)
            for i in action.slots:
                self.tracer.span("spec", self.slots[i].result.uid,
                                 t_d0, t_s1, tokens=int(per_slot[i]),
                                 drafted=int(n_drafted[i]),
                                 accepted=int(n_acc[i]),
                                 k=k_eff, cycles=c_eff)
        # nonzero is row-major (t ascending), so per-slot emission order is
        # preserved without scanning all c*(k+1) x n_slots cells in Python
        for t, i in zip(*np.nonzero(emitted)):
            slot = self.slots[i]
            slot.prev_token = slot.last_token
            self._emit(slot, int(toks[t, i]), finished)
        t_f1 = clk()
        ph["token_fanout"] = t_f1 - t_s1
        self.stats["decode_ticks"] += 1
        self.stats["decode_slot_steps"] += int(emitted.sum())
        return finished

    # ------------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            arrivals_s: Optional[Sequence[float]] = None,
            arrival_ticks: Optional[Sequence[int]] = None,
            ) -> Dict[int, RequestResult]:
        """Drive the given requests to completion; returns results keyed by
        the request's INDEX in ``requests`` (uids are engine-internal).

        ``arrivals_s``: wall-clock offsets (trace replay);
        ``arrival_ticks``: deterministic engine-tick offsets (tests). With
        neither, everything is submitted up front."""
        if arrivals_s is not None and arrival_ticks is not None:
            raise ValueError("pass at most one of arrivals_s/arrival_ticks")
        if self.has_work:
            raise RuntimeError(
                "run() requires an idle engine: requests already queued via "
                "submit() have no index in this run's result map — drain "
                "them with step() first")
        offsets = (arrivals_s if arrivals_s is not None else arrival_ticks
                   if arrival_ticks is not None else [0] * len(requests))
        pending = sorted(zip(offsets, range(len(requests))), key=lambda p: p[0])
        by_wall = arrivals_s is not None
        t0 = self.clock()
        tick0 = self.ticks          # offsets are relative to THIS run's start
        uid_to_index: Dict[int, int] = {}
        results: Dict[int, RequestResult] = {}
        while pending or self.has_work:
            now = (self.clock() - t0) if by_wall else self.ticks - tick0
            while pending and pending[0][0] <= now:
                _, i = pending.pop(0)
                uid_to_index[self.submit(requests[i])] = i
            if self.has_work:
                for res in self.step():
                    results[uid_to_index[res.uid]] = res
            elif pending:
                if by_wall:
                    # idle engine: sleep until the next arrival is actually
                    # due (a fixed cap here was a 1 ms busy-wait per loop)
                    time.sleep(max(0.0, pending[0][0] - now))
                else:
                    self.ticks += 1     # idle tick until the next arrival
        return results


# ------------------------------------------------------------------- stats
def latency_histogram(values_s: Sequence[float]) -> Dict[str, Any]:
    """Seconds -> the shared fixed-bucket latency histogram (JSON form);
    every latency/TTFT distribution in BENCH_serving.json uses these
    buckets so bench_diff can compare shapes across baselines."""
    h = telemetry.Histogram("latency_s",
                            buckets=telemetry.schema.LATENCY_BUCKETS_S)
    for v in values_s:
        h.observe(v)
    return h.to_dict()


def summarize_results(results: Dict[int, RequestResult],
                      wall_s: float) -> Dict[str, Any]:
    """Throughput + nearest-rank latency/TTFT percentiles over a finished
    result set (shared by `serve --engine` and the serving bench), plus
    the full latency/TTFT distributions as fixed-bucket histograms. An
    empty result set (a bench variant whose requests all failed admission,
    or a zero-request trace) yields a zeroed summary instead of an
    IndexError from the nearest-rank lookup."""
    if not results:
        return {"n_requests": 0, "out_tokens": 0, "tokens_per_s": 0.0,
                "latency_p50_ms": 0.0, "latency_p95_ms": 0.0,
                "ttft_p50_ms": 0.0, "ttft_p95_ms": 0.0,
                "latency_hist": latency_histogram(()),
                "ttft_hist": latency_histogram(())}
    lat = sorted(r.latency_s for r in results.values())
    ttft = sorted(r.ttft_s for r in results.values())

    def pct(xs, q):
        return xs[max(0, -(-int(q * len(xs)) // 100) - 1)]

    out_tokens = sum(len(r.tokens) for r in results.values())
    return {
        "n_requests": len(results),
        "out_tokens": out_tokens,
        "tokens_per_s": out_tokens / max(wall_s, 1e-9),
        "latency_p50_ms": pct(lat, 50) * 1e3,
        "latency_p95_ms": pct(lat, 95) * 1e3,
        "ttft_p50_ms": pct(ttft, 50) * 1e3,
        "ttft_p95_ms": pct(ttft, 95) * 1e3,
        "latency_hist": latency_histogram(lat),
        "ttft_hist": latency_histogram(ttft),
    }


# ---------------------------------------------------------------- reference
@functools.lru_cache(maxsize=8)
def _serial_step(cfg, ctx):
    """One jitted decode step per (cfg, ctx) — serial_decode is called once
    per verified request, and a fresh jit(lambda) per call would recompile
    the (1, 1) decode graph every time."""
    return jax.jit(lambda p, st, t: lm.decode_step(p, cfg, st, t, ctx))


@functools.lru_cache(maxsize=8)
def _serial_sampler(scfg: smp.SamplingConfig):
    """Jitted (logits, pos) -> token for one SamplingConfig — the SAME key
    rule (seed x absolute position) the engine's batched scan uses, so a
    fixed seed yields identical tokens serial vs engine."""
    base = smp.base_key(scfg)
    return jax.jit(lambda lg, p: smp.sample(lg, scfg, smp.token_key(base, p)))


def serial_decode(params, cfg, prompt: Sequence[int], max_new_tokens: int,
                  ctx: Optional[RunContext] = None, max_seq: int = 128,
                  eos_id: Optional[int] = None,
                  sampling: Optional[smp.SamplingConfig] = None) -> List[int]:
    """The serial single-request path the engine must match token-for-token:
    whole-prompt prefill, then one decode step per token. Greedy by default;
    a non-greedy ``sampling`` draws each token with the shared
    position-derived key rule."""
    ctx = ctx or default_ctx()
    scfg = sampling or smp.GREEDY
    prompt = np.asarray(prompt, np.int32)
    state = lm.init_decode_state(cfg, 1, max_seq, ctx, params=params)
    step = _serial_step(cfg, ctx)
    sampler = None if scfg.is_greedy else _serial_sampler(scfg)

    def pick(logits_row, pos: int) -> int:
        return _pick_token(logits_row, pos, sampler)

    logits, state = step(params, state, jnp.asarray(prompt[None]))
    out: List[int] = []
    tok = pick(logits[0, -1], int(prompt.size))
    while True:
        out.append(tok)
        if tok == eos_id or len(out) >= max_new_tokens:
            return out
        logits, state = step(params, state,
                             jnp.full((1, 1), tok, jnp.int32))
        tok = pick(logits[0, -1], int(prompt.size) + len(out))
