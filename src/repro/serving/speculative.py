"""Self-speculative decoding: the HQP artifact drafts, bf16 verifies.

HQP's quality bound (Δacc ≤ 1.5% vs the dense parent) is exactly what makes
the compressed artifact a high-acceptance *drafter* for its own
full-precision parent: the drafter proposes K cheap tokens, the verifier
scores all K+1 positions in ONE ``route="prefill"`` pass, and rejection
sampling keeps every emitted token distributed exactly as the verifier
alone would have produced — in greedy mode, bit-identically (``serve
--engine --spec-k 4 --verify`` self-checks against serial bf16 decode).

One speculative cycle per engine decode dispatch, entirely on device
(ONE host sync per cycle, emitting 1..K+1 tokens):

  draft    K drafter ``decode`` steps in a ``lax.scan`` over the drafter's
           own compacted pool (PR 3's per-slot machinery), plus one
           write-only step so the drafter cache has no KV gap when every
           draft accepts;
  verify   one verifier pass over the (B, K+1) chunk ``[t0, d1..dK]``
           through ``lm.verify_step`` (the ``prefill`` route — PR 4's
           absolute causal limits make position i of the chunk
           bit-identical to a serial decode of the same prefix);
  accept   greedy: longest prefix with ``d_{i+1} == argmax(verifier_i)``,
           then the verifier's own token as correction/bonus.
           sampling: standard modified rejection sampling — accept
           ``d_{i+1}`` with prob ``min(1, p_i(d)/q_i(d))``, resample
           rejections from ``normalize(max(p - q, 0))``;
  rollback both pools' ``pos`` drop to the accepted length
           (``state_pool.rollback_slots``) — stale candidate KV past the
           new ``pos`` is masked by the absolute causal limit of every
           later attend and overwritten before it can become visible, the
           same invariant that makes slot reuse safe.

Restriction: rollback-by-``pos`` only exists for position-indexed KV
caches, so speculative mode refuses layer patterns with recurrent state
(Mamba/xLSTM) at construction.

The dual pools may have DIFFERENT cache shapes: the drafter pool sizes
itself from the compacted artifact's params (pruned KV heads, INT8 KV),
the verifier pool from the bf16 parent — ``Engine`` owns both and passes
them per dispatch.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.invariants import declare_invariants
from repro.models import lm
from repro.serving import sampling as smp
from repro.serving import state_pool as sp
from repro.sharding.ctx import RunContext, default_ctx


def check_drafter_compat(cfg, manifest) -> None:
    """Refuse a drafter artifact built for a different model family before
    any device work runs. ``manifest`` is an ``HQPManifest`` (or None to
    skip — e.g. a drafter built in-process from the verifier's own params).
    Pre-speculative artifacts (no recorded hash) pass with a vocab check
    only when they recorded one."""
    if manifest is None:
        return
    from repro.compress import arch_fingerprint
    want = arch_fingerprint(cfg)
    if manifest.arch_hash is not None and manifest.arch_hash != want:
        raise ValueError(
            f"drafter artifact arch_hash {manifest.arch_hash!r} (built for "
            f"{manifest.arch!r}) does not match the verifier config "
            f"{getattr(cfg, 'name', '?')!r} (fingerprint {want!r}) — a "
            f"speculative drafter must share its verifier's vocab/arch")
    if (manifest.vocab_size is not None
            and manifest.vocab_size != getattr(cfg, "vocab_size", None)):
        raise ValueError(
            f"drafter artifact vocab_size {manifest.vocab_size} != verifier "
            f"vocab_size {getattr(cfg, 'vocab_size', None)} — draft token "
            f"ids would not be verifier token ids")


class SpecDecoder:
    """Holds the two parameter sets and the fused speculative device step.

    ``spec_fn(draft_params, verify_params, draft_pool, verify_pool, table,
    prev, tokens, active, eos, budget, k, cycles, window)`` is jitted with
    STATIC ``(k, cycles, window)`` and donated pools; it runs ``cycles``
    draft→verify cycles before the single host sync and returns ``(toks
    (cycles*(k+1), B), emitted (cycles*(k+1), B), n_acc_emit (B,),
    n_drafted (B,), draft_pool, verify_pool)`` where ``emitted[t, i]``
    marks a real token for slot i in emission order, ``n_acc_emit`` counts
    how many of slot i's emitted tokens were accepted drafts (the
    acceptance-rate numerator; corrections/bonus tokens are emitted but
    not "accepted"), and ``n_drafted`` the drafts proposed to it while
    live (the denominator).

    ``table`` is the (B, max_pages) page table when ``paged=True`` — ONE
    table addresses both pools (their arenas are allocated page-for-page in
    lockstep and the pools' ``pos`` stay aligned); the engine redirects
    inactive rows to the trash page before dispatch. A dummy (B, 1) zeros
    array in contiguous mode."""

    def __init__(self, cfg, draft_params: Any, verify_params: Any,
                 ctx: Optional[RunContext] = None,
                 draft_ctx: Optional[RunContext] = None, k: int = 4,
                 cycles: int = 1,
                 sampling: Optional[smp.SamplingConfig] = None,
                 draft_manifest=None, paged: bool = False):
        if k < 1:
            raise ValueError(f"spec k must be >= 1, got {k}")
        if cycles < 1:
            raise ValueError(f"spec cycles must be >= 1, got {cycles}")
        kinds = {kind for kind, _ in lm.layer_specs(cfg)}
        if kinds - {"attn"}:
            raise NotImplementedError(
                f"speculative decoding rolls caches back by pos, which only "
                f"position-indexed KV caches support; pattern has recurrent "
                f"blocks {sorted(kinds - {'attn'})} whose state cannot "
                f"rewind")
        check_drafter_compat(cfg, draft_manifest)
        self.cfg = cfg
        self.k = k
        self.cycles = cycles
        self.last_plan = None          # (k_eff, cycles_eff) of the newest
                                       # plan() call — see plan()
        self.draft_params = draft_params
        self.verify_params = verify_params
        self.ctx = ctx or default_ctx()
        self.draft_ctx = draft_ctx or self.ctx
        self.sampling = sampling or smp.GREEDY
        self.paged = paged
        # §15: one host sync per speculative dispatch, both pools updated
        # in place, bf16 KV never round-trips through f32 (the drafter's
        # INT8 arena and the verifier's uint16 arena alike)
        self.spec_fn = declare_invariants(
            "engine.spec", host_syncs=1, donated=("dpool", "vpool"),
            forbid_f32_roundtrip_on=("kv",),
            static_argnums=(10, 11, 12),
        )(jax.jit(self._build_spec(),
                  static_argnums=(10, 11, 12),
                  donate_argnums=(2, 3)))

    def plan(self, max_pos: int, max_seq: int,
             max_budget: int) -> Tuple[int, int]:
        """Per-dispatch ``(k_eff, cycles_eff)``, capped two ways:

        * in-bounds: the vmapped ``dynamic_update_slice`` KV write CLAMPS
          an out-of-range start — silently overwriting valid history — so
          no chunk may write past ``max_seq``; C cycles write at most
          ``C*(k+1)`` positions past ``max_pos``;
        * right-sized: ``max_budget`` (the largest remaining token budget
          over the live slots) bounds useful work — a request two tokens
          from its length cap must not pay for k drafts, so the endgame
          dispatch shrinks instead of drafting tokens nobody can emit.

        ``k_eff`` is always >= 1: a live slot has budget >= 1, and
        ``submit`` bounds prompt+budget by ``max_seq``.

        The chosen plan is recorded as ``last_plan`` so telemetry (the
        engine's spec-cycle span annotations, an operator poking at a
        live decoder) reads the plan the dispatch actually ran rather
        than re-deriving it."""
        avail = max_seq - 1 - max_pos
        k_eff = max(1, min(self.k, avail, max_budget))
        cyc = max(1, min(self.cycles,
                         (avail + 1) // (k_eff + 1),
                         -(-max_budget // (k_eff + 1))))
        self.last_plan = (k_eff, cyc)
        return k_eff, cyc

    def _build_spec(self):
        cfg, dctx, vctx = self.cfg, self.draft_ctx, self.ctx
        scfg = self.sampling
        greedy = scfg.is_greedy
        paged = self.paged
        base = smp.base_key(scfg)
        # paged: every model call reads/writes KV through the page table
        # (attached per call — decode_step treats "pages" as input-only and
        # never returns it, so the scan carries keep a constant structure)
        att = (lambda pool, table: dict(pool, pages=table)) if paged \
            else (lambda pool, table: pool)

        def cycle(dparams, vparams, dpool, vpool, table, prev, tokens, live,
                  eos, budget, k, window):
            """One draft→verify→accept→rollback cycle. ``live`` (B,) bool is
            the slots still running THIS dispatch (slots that stopped in an
            earlier cycle stay frozen: their pos never moves, so their cycle
            work deterministically REWRITES the same cache positions with
            identical bits — idempotent, and the host evicts them anyway).
            """
            b = tokens.shape[0]
            pos_c = vpool["pos"]                         # (B,) — == dpool's
                                                         # for live slots

            # ---- draft: one 2-token healing chunk + k-1 decode steps ---
            # The first draft invocation prefills [prev, t0] at positions
            # pos-1..pos: position pos-1 is REWRITTEN with bit-identical KV
            # (same token, same absolute position, same cached prefix) —
            # except after a fully-accepted cycle, where d_k's KV was never
            # drafted and this chunk heals the one-position gap. That folds
            # the old trailing "write-only" drafter step into the next
            # cycle's first invocation: k draft tokens cost k invocations,
            # not k+1.
            chunk2 = jnp.concatenate([prev, tokens], axis=1)      # (B, 2)
            dlogits, dpool = lm.decode_step(
                dparams, cfg, att({"caches": dpool["caches"],
                                   "pos": dpool["pos"] - 1}, table),
                chunk2, dctx, window=window, route="prefill")
            lg0 = dlogits[:, -1]
            if greedy:
                d1 = jnp.argmax(lg0, axis=-1).astype(jnp.int32)
                q1 = jnp.zeros((), jnp.float32)          # unused in greedy
            else:
                q1 = smp.probs(lg0, scfg)
                d1 = smp.sample_batch(lg0, scfg, base, dpool["pos"])

            def body(carry, _):
                dpool, tok = carry
                logits, new = lm.decode_step(dparams, cfg, att(dpool, table),
                                             tok, dctx, window=window,
                                             route="decode")
                lg = logits[:, -1]
                if greedy:
                    nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                    q = jnp.zeros((), jnp.float32)
                else:
                    q = smp.probs(lg, scfg)
                    nxt = smp.sample_batch(lg, scfg, base, new["pos"])
                tok = jnp.where(live, nxt, tok[:, 0])[:, None]
                return (new, tok), (nxt, q)

            (dpool, _), (drafts, qprobs) = jax.lax.scan(
                body, (dpool, jnp.where(live, d1, tokens[:, 0])[:, None]),
                None, length=k - 1)
            d_bk = jnp.concatenate(
                [d1[:, None], jnp.moveaxis(drafts, 0, 1)], axis=1)  # (B, k)
            if not greedy:
                qprobs = jnp.concatenate(
                    [q1[:, None], jnp.moveaxis(qprobs, 0, 1)], axis=1)

            # ---- verify: ONE multi-position pass on the verifier -------
            chunk = jnp.concatenate([tokens, d_bk], axis=1)   # (B, k+1)
            vlogits, vpool = lm.verify_step(vparams, cfg, att(vpool, table),
                                            chunk, vctx, window=window)

            # ---- accept ------------------------------------------------
            if greedy:
                v = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)  # (B,k+1)
                match = (d_bk == v[:, :k]).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                corr = v          # correction at index i is the verifier's
                                  # own greedy token — serial-identical
            else:
                p = smp.probs(vlogits, scfg)             # (B, k+1, V)
                q = qprobs                               # (B, k, V)
                dpos = pos_c[:, None] + 1 + jnp.arange(k)[None, :]
                ukey = jax.vmap(jax.vmap(
                    lambda pp: smp.token_key(base, pp, smp.LANE_ACCEPT)))(dpos)
                u = jax.vmap(jax.vmap(jax.random.uniform))(ukey)
                p_d = jnp.take_along_axis(p[:, :k], d_bk[..., None],
                                          axis=-1)[..., 0]
                q_d = jnp.take_along_axis(q, d_bk[..., None],
                                          axis=-1)[..., 0]
                accept = (u * q_d <= p_d).astype(jnp.int32)   # u <= p/q
                n_acc = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)
                # corrections: residual max(p-q, 0) normalized at i < k (the
                # rejected position's leftover verifier mass); the bonus at
                # i == k samples the verifier distribution directly. A zero
                # residual (p == q exactly) falls back to p — that lane is
                # only read when a rejection happened, but NaNs from 0/0
                # must not exist even masked.
                res = jnp.maximum(p[:, :k] - q, 0.0)
                rsum = jnp.sum(res, axis=-1, keepdims=True)
                res = jnp.where(rsum > 0, res / jnp.maximum(rsum, 1e-30),
                                p[:, :k])
                cdist = jnp.concatenate([res, p[:, k:]], axis=1)  # (B,k+1,V)
                cpos = pos_c[:, None] + 1 + jnp.arange(k + 1)[None, :]
                ckey = jax.vmap(jax.vmap(
                    lambda pp: smp.token_key(base, pp, smp.LANE_RESIDUAL)))(
                        cpos)
                corr = jax.vmap(jax.vmap(
                    lambda kk, d: jax.random.categorical(kk, jnp.log(d))))(
                        ckey, cdist).astype(jnp.int32)

            # ---- emit with EOS/budget truncation (host semantics) ------
            # Emission is a PREFIX of the k+1 candidate positions: index i
            # emits iff i <= n_acc (accepted drafts + one correction/bonus),
            # i < budget, and no earlier emitted token hit EOS — so every
            # gate is a vectorized prefix mask, no per-position unroll.
            i_idx = jnp.arange(k + 1)[None, :]                    # (1, k+1)
            d_pad = jnp.concatenate(
                [d_bk, jnp.zeros((b, 1), jnp.int32)], axis=1)
            cand = jnp.where(i_idx < n_acc[:, None], d_pad, corr)  # (B, k+1)
            prefix = (live[:, None] & (i_idx <= n_acc[:, None])
                      & (i_idx < budget[:, None]))
            eos_hit = (eos[:, None] >= 0) & (cand == eos[:, None]) & prefix
            eos_before = jnp.cumsum(eos_hit, axis=1) - eos_hit    # exclusive
            emit = prefix & (eos_before == 0)
            n_emit = jnp.sum(emit, axis=1).astype(jnp.int32)
            n_acc_emit = jnp.sum(emit & (i_idx < n_acc[:, None]),
                                 axis=1).astype(jnp.int32)

            # ---- per-cycle rollback + next-cycle carries ---------------
            # pos drops to the accepted length; non-live rows have
            # n_emit == 0, but their pos still advanced k+1 inside this
            # cycle's model calls, so the rollback mask must cover EVERY
            # row (frozen and mid-prefill included), not just live ones
            pos_new = pos_c + n_emit
            every = jnp.ones_like(live)
            dpool = sp.rollback_slots(dpool, pos_new, every)
            vpool = sp.rollback_slots(vpool, pos_new, every)
            last_i = jnp.clip(n_emit - 1, 0, k)[:, None]
            prev_i = jnp.clip(n_emit - 2, 0, k)[:, None]
            new_last = jnp.take_along_axis(cand, last_i, axis=1)[:, 0]
            new_prev = jnp.take_along_axis(cand, prev_i, axis=1)[:, 0]
            tokens2 = jnp.where(n_emit >= 1, new_last, tokens[:, 0])[:, None]
            prev2 = jnp.where(n_emit >= 2, new_prev,
                              jnp.where(n_emit == 1, tokens[:, 0],
                                        prev[:, 0]))[:, None]
            stopped = jnp.any(eos_hit & emit, axis=1) | (budget - n_emit <= 0)
            live2 = live & ~stopped
            budget2 = budget - n_emit
            drafted = jnp.where(live, k, 0).astype(jnp.int32)
            return (dpool, vpool, prev2, tokens2, live2, budget2,
                    jnp.where(emit, cand, 0), emit, n_acc_emit, drafted)

        def spec(dparams, vparams, dpool, vpool, table, prev, tokens,
                 active, eos, budget, k, cycles, window):
            """prev/tokens (B, 1) i32: the two newest emitted tokens per
            slot (``prev`` at position pos-1, ``tokens`` pending at pos);
            active (B,) bool; eos (B,) i32 (-1 = none); budget (B,) i32
            remaining tokens. Runs ``cycles`` draft→verify cycles before
            the single host sync; slots stopping mid-dispatch freeze.

            Slots are NOT select-masked per model invocation (the plain
            decode scan must freeze mid-scan stoppers bit-exactly; here
            frozen slots' work is idempotent and mid-prefill slots are
            restored wholesale below) — two full-pool selects per dispatch
            instead of per-step. In paged mode the KV arenas cannot be
            select-restored (no slot axis); inactive rows are instead
            redirected to the trash page in ``table`` by the engine, so the
            select only restores their recurrent state and ``pos``."""
            dpool0, vpool0 = dpool, vpool

            def step(carry, _):
                dpool, vpool, prev, tokens, live, eos_, budget = carry
                (dpool, vpool, prev, tokens, live, budget,
                 outs, emit, n_acc, drafted) = cycle(
                    dparams, vparams, dpool, vpool, table, prev, tokens,
                    live, eos_, budget, k, window)
                return ((dpool, vpool, prev, tokens, live, eos_, budget),
                        (outs, emit, n_acc, drafted))

            ((dpool, vpool, _, _, _, _, _),
             (outs, emits, n_accs, drafteds)) = jax.lax.scan(
                step, (dpool, vpool, prev, tokens, active, eos, budget),
                None, length=cycles)

            # restore slots that were inactive at dispatch (mid-prefill /
            # free): their cycle work wrote garbage at their own positions
            dpool = sp.select_slots(dpool, dpool0, active, paged)
            vpool = sp.select_slots(vpool, vpool0, active, paged)
            # (C, B, k+1) -> (C*(k+1), B) in per-slot emission order
            outs = jnp.moveaxis(outs, 2, 1).reshape(cycles * (k + 1), -1)
            emits = jnp.moveaxis(emits, 2, 1).reshape(cycles * (k + 1), -1)
            return (outs, emits, jnp.sum(n_accs, axis=0),
                    jnp.sum(drafteds, axis=0), dpool, vpool)

        return spec
