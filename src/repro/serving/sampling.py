"""Token sampling for the serving stack (greedy / temperature / top-k).

One ``SamplingConfig`` drives every decode surface — the serial reference
path (``engine.serial_decode``), the launcher's lockstep loop, the engine's
on-device multi-step decode scan, and the speculative drafter — so "same
seed => same tokens" holds across all of them by construction.

Determinism contract: the token emitted at absolute sequence position ``p``
(the position its KV will be written at) is sampled with
``token_key(base_key(cfg), p)``. The key depends only on (seed, position) —
never on slot index, engine tick, or dispatch batching — so the engine's
batched scan and the serial per-token loop draw identical randomness for
identical requests. A deliberate consequence: two requests with the SAME
prompt under the SAME seed emit byte-identical samples (reproducible
serving — the batch composition can never perturb a request's output);
callers wanting diverse samples for duplicate prompts vary ``seed`` per
request. Speculative decoding reserves two extra key *lanes* (acceptance
uniforms, residual resampling) so its rejection sampler never reuses a
draft key.

``temperature == 0`` is greedy: callers branch STATICALLY on
``SamplingConfig.is_greedy`` and take a pure ``argmax`` path with no keys,
keeping the default serving mode bit-identical to the pre-sampling engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# key lanes: every speculative phase folds its lane first, so draft tokens,
# acceptance uniforms, and residual resamples never share randomness
LANE_TOKEN = 0        # ordinary next-token sampling (serial, engine, drafts)
LANE_ACCEPT = 1       # speculative acceptance uniforms
LANE_RESIDUAL = 2     # speculative rejection resampling


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """``temperature=0`` => greedy argmax (keys unused); ``top_k=0`` => the
    full vocabulary. Frozen/hashable so jitted callables can close over it
    statically."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingConfig()


def base_key(cfg: SamplingConfig) -> jax.Array:
    return jax.random.PRNGKey(cfg.seed)


def token_key(base: jax.Array, pos, lane: int = LANE_TOKEN) -> jax.Array:
    """Key for the token at absolute position ``pos`` (scalar or traced)."""
    return jax.random.fold_in(jax.random.fold_in(base, lane), pos)


def warp_logits(logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """Top-k mask + temperature scale on the last axis, in f32.

    Masked entries go to -inf, so downstream ``softmax``/``categorical``
    assign them exactly zero probability. Ties at the top-k boundary resolve
    by ``jax.lax.top_k``'s stable (lowest-index-first) order — deterministic,
    matching across the batched and serial paths."""
    lg = logits.astype(jnp.float32)
    if cfg.top_k > 0 and cfg.top_k < lg.shape[-1]:
        kth = jax.lax.top_k(lg, cfg.top_k)[0][..., -1:]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    if not cfg.is_greedy:
        lg = lg / cfg.temperature
    return lg


def probs(logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """Post-warp probabilities (f32) — the p/q distributions speculative
    rejection sampling compares must be the EXACT distributions the drafter
    sampled from and the verifier would sample from."""
    return jax.nn.softmax(warp_logits(logits, cfg), axis=-1)


def sample(logits: jax.Array, cfg: SamplingConfig, key: jax.Array) -> jax.Array:
    """One token from a single (V,) logits row. Greedy ignores ``key``."""
    if cfg.is_greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, warp_logits(logits, cfg)).astype(
        jnp.int32)


def sample_batch(logits: jax.Array, cfg: SamplingConfig, base: jax.Array,
                 pos: jax.Array, lane: int = LANE_TOKEN) -> jax.Array:
    """Per-slot sampling for the engine's batched scan: ``logits`` (B, V),
    ``pos`` (B,) absolute positions. Each row draws with its own
    position-derived key, so a slot's tokens are independent of which other
    slots happen to share its dispatch."""
    if cfg.is_greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = jax.vmap(lambda p: token_key(base, p, lane))(pos)
    return jax.vmap(lambda lg, k: sample(lg, cfg, k))(logits, keys)
