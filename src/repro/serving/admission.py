"""Deadline-feasibility admission control (DESIGN.md §14).

PR 7's admission bound sheds *blindly*: once ``n_slots + queue_depth``
requests are in flight every submit is rejected with a static
``Retry-After``, and — worse — a request whose deadline cannot possibly
be met is admitted anyway, burns slot time, and dies in the deadline
sweep.  ``AdmissionController`` closes that loop with the measurements
the engine already produces:

  * ``observe(prefill_tokens, decode_tokens, wall_s)`` — fed one engine
    step at a time via ``observe_step(Engine.last_step)`` (the engine's
    own phase-time attribution; the service no longer re-times the step
    with a parallel clock read), it maintains two EWMAs: aggregate
    prefill throughput
    and aggregate decode throughput, in tokens/second.  Separate rates
    because the two phases have very different cost per token (a prefill
    chunk amortizes weights over many tokens; decode is one token per
    pass per slot).
  * ``feasible(prompt_len, max_new_tokens, backlog)`` — at submit time,
    predict when the new request would finish if admitted *behind* the
    current backlog (remaining prefill + decode tokens of every live
    request, which the service computes exactly from its tickets and the
    engine's per-slot prefill progress):

        predicted_s = safety * (  (backlog.prefill + prompt_len) / prefill_rate
                                + (backlog.decode  + max_new)    / decode_rate )

    The engine time-slices prefill against decode, so total completion
    time is the sum of both phases' work at their measured aggregate
    rates; ``safety`` (> 1) absorbs EWMA lag and scheduling jitter —
    shedding slightly too eagerly near the knee is the safe failure
    direction, admitting a doomed request is not.
  * an **honest Retry-After**: if the request misses its deadline by
    ``excess = predicted_s - deadline_s`` seconds, the backlog must
    drain for ``excess`` seconds before the same submit becomes
    feasible — that (clamped to ``[retry_floor_s, retry_cap_s]``) is
    what the 429 advertises, instead of a constant.

The controller is pure arithmetic over durations — no clock, no HTTP,
no engine reference — so it is unit-testable by feeding synthetic
observations; the *service* owns the (injectable) clock and the backlog
bookkeeping.  Until ``min_observations`` samples of each rate have
arrived the controller reports ``warm == False`` and the service admits
on the static bound alone (the hard cap stays regardless: feasibility
never admits past ``n_slots + queue_depth``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    ewma_alpha: float = 0.3        # EWMA smoothing: weight of the newest sample
    safety: float = 1.5            # predicted-completion multiplier (> 1);
                                   # absorbs EWMA lag + scheduling jitter
    min_observations: int = 3      # samples of EACH rate before predictions
                                   # engage (cold controller admits statically)
    retry_floor_s: float = 0.05    # Retry-After clamp (advertised honesty
    retry_cap_s: float = 30.0      # has limits: sub-50ms retries just hammer)

    def __post_init__(self):
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError(f"ewma_alpha must be in (0, 1], got "
                             f"{self.ewma_alpha}")
        if self.safety < 1.0:
            raise ValueError(f"safety must be >= 1, got {self.safety}")
        if self.min_observations < 1:
            raise ValueError(f"min_observations must be >= 1, got "
                             f"{self.min_observations}")
        if not (0.0 < self.retry_floor_s <= self.retry_cap_s):
            raise ValueError(f"need 0 < retry_floor_s <= retry_cap_s, got "
                             f"{self.retry_floor_s}..{self.retry_cap_s}")


@dataclasses.dataclass(frozen=True)
class Verdict:
    """One feasibility decision: admit or shed, plus the numbers behind it
    (``predicted_s`` includes the safety factor; ``retry_after_s`` is the
    honest backlog-drain estimate, clamped)."""
    feasible: bool
    predicted_s: float
    retry_after_s: float


class AdmissionController:
    """EWMA throughput tracker + deadline-feasibility predictor."""

    def __init__(self, cfg: Optional[AdmissionConfig] = None):
        self.cfg = cfg or AdmissionConfig()
        self.prefill_tok_s: Optional[float] = None   # EWMA, tokens/second
        self.decode_tok_s: Optional[float] = None
        self._n_prefill = 0
        self._n_decode = 0

    # --------------------------------------------------------------- measure
    def observe(self, prefill_tokens: int, decode_tokens: int,
                wall_s: float) -> None:
        """Fold one engine step into the rate EWMAs. ``prefill_tokens`` /
        ``decode_tokens`` are the step's ``Engine.stats`` deltas
        (``prefill_tokens`` / ``accepted_tokens``); ``wall_s`` the step's
        wall time on the service clock. Steps that moved no tokens of a
        kind (or report a non-positive wall) leave that EWMA untouched."""
        if wall_s <= 0.0:
            return
        a = self.cfg.ewma_alpha
        if prefill_tokens > 0:
            r = prefill_tokens / wall_s
            self.prefill_tok_s = (r if self.prefill_tok_s is None
                                  else (1 - a) * self.prefill_tok_s + a * r)
            self._n_prefill += 1
        if decode_tokens > 0:
            r = decode_tokens / wall_s
            self.decode_tok_s = (r if self.decode_tok_s is None
                                 else (1 - a) * self.decode_tok_s + a * r)
            self._n_decode += 1

    def observe_step(self, last_step) -> None:
        """Fold ``Engine.last_step`` (the engine's own phase-time
        attribution, measured on the engine's injectable clock around the
        step it describes) into the EWMAs. This is the ONLY measurement
        path in serving: the service hands the engine's record straight
        here instead of re-timing ``step()`` with a second clock read and
        re-deriving token deltas from stats — one measurement, two
        consumers (these EWMAs and the phase histograms)."""
        if not last_step:
            return
        self.observe(int(last_step.get("prefill_tokens", 0)),
                     int(last_step.get("decode_tokens", 0)),
                     float(last_step.get("wall_s", 0.0)))

    @property
    def warm(self) -> bool:
        """Both rates observed at least ``min_observations`` times —
        predictions are meaningful."""
        n = self.cfg.min_observations
        return self._n_prefill >= n and self._n_decode >= n

    # --------------------------------------------------------------- predict
    def work_s(self, prefill_tokens: int, decode_tokens: int) -> float:
        """Safety-scaled wall time to move the given token counts through
        the engine at the current EWMA rates. Requires ``warm``."""
        return self.cfg.safety * (
            prefill_tokens / self.prefill_tok_s
            + decode_tokens / self.decode_tok_s)

    def clamp_retry(self, retry_s: float) -> float:
        return min(max(retry_s, self.cfg.retry_floor_s), self.cfg.retry_cap_s)

    def feasible(self, prompt_len: int, max_new_tokens: int,
                 backlog: Tuple[int, int], deadline_s: float) -> Verdict:
        """Would a request of this shape, submitted *now* behind
        ``backlog = (prefill_tokens, decode_tokens)`` of live work, finish
        within ``deadline_s``?  Requires ``warm`` (the service checks)."""
        bp, bd = backlog
        predicted = self.work_s(bp + prompt_len, bd + max_new_tokens)
        if predicted <= deadline_s:
            return Verdict(True, predicted, 0.0)
        # the backlog drains at roughly the same rates the prediction was
        # priced at, so after `excess` seconds the identical submit comes
        # in under the deadline — that is the honest Retry-After. When the
        # request's OWN work alone exceeds the deadline no retry helps;
        # the clamp still bounds what we advertise.
        excess = predicted - deadline_s
        return Verdict(False, predicted, self.clamp_retry(excess))
