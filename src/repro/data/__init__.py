from repro.data.synthetic import SyntheticImages, SyntheticTokens  # noqa: F401
