from repro.data.synthetic import SyntheticImages, SyntheticTokens  # noqa: F401

__all__ = ["SyntheticImages", "SyntheticTokens"]
