"""Deterministic synthetic datasets (offline container — no ImageNet).

Images: class-template + structured distractors + noise — learnable to
~95% by the small CNNs in a few hundred steps, and degrades *smoothly* under
channel masking, which is what the HQP conditional loop needs to exercise
its accept/reject boundary realistically.

Tokens: sparse order-1 Markov chains — the LM learns the transition table;
next-token top-1 accuracy (bounded by the chain's determinism) is the
validation metric the Δ_ax constraint is enforced against.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class SyntheticImages:
    def __init__(self, n: int, n_classes: int = 10, image_size: int = 32,
                 seed: int = 0, noise: float = 0.35, template_seed: int = 0):
        # class templates are shared across splits (template_seed), only the
        # sampling differs per split (seed) — train/val/calib measure the
        # SAME task
        trng = np.random.RandomState(template_seed)
        rng = np.random.RandomState(seed + 1)
        k = image_size
        self.templates = trng.randn(n_classes, k, k, 3).astype(np.float32)
        for c in range(n_classes):
            # low-pass: keep the templates smooth so conv features matter
            t = self.templates[c]
            t = (t + np.roll(t, 1, 0) + np.roll(t, 1, 1)
                 + np.roll(t, 2, 0) + np.roll(t, 2, 1)) / 5.0
            self.templates[c] = t / (np.abs(t).max() + 1e-6)
        self.labels = rng.randint(0, n_classes, size=n).astype(np.int32)
        shift = rng.randint(-3, 4, size=(n, 2))
        imgs = np.empty((n, k, k, 3), np.float32)
        for i in range(n):
            t = self.templates[self.labels[i]]
            t = np.roll(t, tuple(shift[i]), axis=(0, 1))
            imgs[i] = t + noise * rng.randn(k, k, 3)
        self.images = imgs.astype(np.float32)

    def __len__(self):
        return len(self.labels)

    def batches(self, batch_size: int, seed: Optional[int] = None,
                epochs: int = 1) -> Iterator[dict]:
        n = len(self)
        idx = np.arange(n)
        rng = np.random.RandomState(seed) if seed is not None else None
        for _ in range(epochs):
            if rng is not None:
                rng.shuffle(idx)
            for i in range(0, n - batch_size + 1, batch_size):
                sel = idx[i:i + batch_size]
                yield {"image": self.images[sel], "label": self.labels[sel]}


class SyntheticTokens:
    def __init__(self, vocab: int, seq_len: int, n_seqs: int,
                 seed: int = 0, branching: int = 4, determinism: float = 0.85):
        rng = np.random.RandomState(seed)
        self.vocab = vocab
        # sparse markov transition: each token has `branching` successors,
        # one dominant with prob `determinism`
        succ = rng.randint(0, vocab, size=(vocab, branching))
        probs = np.full((vocab, branching),
                        (1 - determinism) / max(branching - 1, 1))
        probs[:, 0] = determinism
        seqs = np.empty((n_seqs, seq_len), np.int64)
        state = rng.randint(0, vocab, size=n_seqs)
        for t in range(seq_len):
            seqs[:, t] = state
            # vectorized successor draw
            u = rng.rand(n_seqs)
            pick = np.where(u < determinism, 0,
                            rng.randint(1, branching, size=n_seqs))
            state = succ[state, pick]
        self.seqs = seqs.astype(np.int32)
        self.best_acc = determinism  # ceiling for next-token accuracy

    def batches(self, batch_size: int, seed: Optional[int] = None,
                epochs: int = 1) -> Iterator[dict]:
        n = len(self.seqs)
        idx = np.arange(n)
        rng = np.random.RandomState(seed) if seed is not None else None
        for _ in range(epochs):
            if rng is not None:
                rng.shuffle(idx)
            for i in range(0, n - batch_size + 1, batch_size):
                yield {"tokens": self.seqs[idx[i:i + batch_size]]}
