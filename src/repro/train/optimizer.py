"""AdamW with optionally INT8-blockwise first/second moments.

At arctic-480b scale, fp32 (m, v) = 3.8 TB — over budget even fully sharded
on 256 chips. The int8-blockwise state (one f32 scale per 256-element block,
à la 8-bit Adam) cuts optimizer state 3.9x and is the paper's quantization
insight applied to *training* state (beyond-paper, recorded in EXPERIMENTS.md
§Perf). Dynamics match fp32 AdamW to ~1e-2 relative on the smoke models
(tested in tests/test_optimizer.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class _Upd:
    """Opaque (param, m, v) triple — a pytree *leaf* for the unzip below."""
    __slots__ = ("p", "m", "v")

    def __init__(self, p, m, v):
        self.p, self.m, self.v = p, m, v


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    state_dtype: str = "f32"         # "f32" | "int8"
    grad_clip: float = 1.0


# ---------------------------------------------------------- int8 state codec
# Param-shaped codec: q keeps the PARAM's shape (int8) and scales are blocked
# along the last axis only, so the optimizer state inherits the param's
# PartitionSpec verbatim. A flat (n_blocks, 256) layout is NOT sharding-
# compatible with arbitrarily-sharded params — on the 480B-MoE dry-run XLA
# reconciled it with twelve full-tensor (625 GB) f32 all-gathers per step
# (EXPERIMENTS.md §Perf, arctic iteration 1). Param-shaped state keeps the
# update fully local/elementwise.
def _block_dim(last: int) -> int:
    # Per-row scales: one f32 scale per trailing-axis row. Any finer blocking
    # must divide the row's *shard*, or the blocked reshape itself reshards a
    # TP-sharded weight — per-row sidesteps that for every rule in
    # sharding/rules.py while staying within the drift bound of
    # tests/test_optimizer.py.
    return last


def _encode(x: jax.Array, sqrt_map: bool = False) -> Tuple[jax.Array, jax.Array]:
    """f32 (param shape) -> (int8 same shape, f32 scales (.., last/blk)).

    ``sqrt_map``: encode sqrt(x) for the non-negative second moment — linear
    int8 on v starves small entries of resolution and biases 1/sqrt(v);
    sqrt-domain quantization (a la 8-bit Adam's dynamic mapping) keeps the
    update direction within a few percent of fp32 (tests/test_optimizer.py)."""
    if sqrt_map:
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    shape = x.shape if x.ndim else (1,)
    blk = _block_dim(shape[-1])
    g = x.reshape(*shape[:-1], shape[-1] // blk, blk)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale[..., 0]


def _decode(q: jax.Array, scale: jax.Array, shape,
            sqrt_map: bool = False) -> jax.Array:
    shape = tuple(shape) if shape else (1,)
    blk = _block_dim(shape[-1])
    g = q.reshape(*shape[:-1], shape[-1] // blk, blk).astype(jnp.float32)
    out = (g * scale[..., None]).reshape(shape)
    if sqrt_map:
        out = jnp.square(out)
    return out


# ---------------------------------------------------------------- init/update
def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    def zero_state(p):
        if cfg.state_dtype == "int8":
            shape = p.shape if p.ndim else (1,)
            blk = _block_dim(shape[-1])
            return {"q": jnp.zeros(shape, jnp.int8),
                    "s": jnp.zeros((*shape[:-1], shape[-1] // blk),
                                   jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zero_state, params),
            "v": jax.tree.map(zero_state, params)}


def _global_norm(grads: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                 ) -> Tuple[Any, dict]:
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.state_dtype == "int8":
            mf = _decode(m["q"], m["s"], p.shape)
            vf = _decode(v["q"], v["s"], p.shape, sqrt_map=True)
        else:
            mf, vf = m, v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        upd_val = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if p.ndim >= 2:
            upd_val = upd_val + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * upd_val).astype(p.dtype)
        if cfg.state_dtype == "int8":
            mq, ms = _encode(mf)
            vq, vs = _encode(vf, sqrt_map=True)
            return new_p, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return new_p, mf, vf

    out = jax.tree.map(lambda p, g, m, v: _Upd(*upd(p, g, m, v)),
                       params, grads, state["m"], state["v"])
    is_u = lambda t: isinstance(t, _Upd)
    new_params = jax.tree.map(lambda t: t.p, out, is_leaf=is_u)
    new_m = jax.tree.map(lambda t: t.m, out, is_leaf=is_u)
    new_v = jax.tree.map(lambda t: t.v, out, is_leaf=is_u)
    return new_params, {"step": step, "m": new_m, "v": new_v}
