"""Distributed train/serve steps (pjit-ready pure functions).

``make_train_step`` builds the donate-friendly step the launcher jits with
in/out shardings from ``sharding.rules``. Gradient accumulation (microbatches)
is a lax.scan so the global batch stays constant when elastic re-meshing
changes the DP width (launch/elastic.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.sharding.ctx import RunContext
from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(cfg, ctx: RunContext, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1) -> Callable:
    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch, ctx, with_aux=True)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (l, aux), grads = grad_fn(params, batch)
        else:
            def micro(acc, mb):
                (l, aux), g = grad_fn(params, mb)
                gsum, lsum = acc
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), aux

            mbs = jax.tree.map(
                lambda t: t.reshape(num_microbatches,
                                    t.shape[0] // num_microbatches,
                                    *t.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), aux = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            l = lsum / num_microbatches
            aux = jax.tree.map(lambda a: a[-1], aux)
        new_params, new_opt = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": l, **{f"aux/{k}": v for k, v in aux.items()}}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg, ctx: RunContext) -> Callable:
    """Next-token top-1 accuracy (the Δ_ax metric for the LM track)."""
    def eval_step(params, batch):
        hidden, _ = lm.forward(params, cfg, batch, ctx, with_aux=False)
        n_fr = cfg.frontend.n_embeds if cfg.frontend.kind != "none" else 0
        tokens = batch["tokens"]
        h = hidden[:, n_fr:n_fr + tokens.shape[1] - 1]
        logits = lm.logits_fn(params, cfg, h)
        pred = jnp.argmax(logits, axis=-1)
        correct = (pred == tokens[:, 1:]).astype(jnp.float32)
        return jnp.mean(correct)

    return eval_step


def make_serve_step(cfg, ctx: RunContext) -> Callable:
    """One decode step: (params, state, tokens (B,1)) -> (logits, state)."""
    def serve_step(params, state, tokens):
        return lm.decode_step(params, cfg, state, tokens, ctx)

    return serve_step


def make_prefill_step(cfg, ctx: RunContext) -> Callable:
    def prefill_step(params, state, tokens):
        return lm.decode_step(params, cfg, state, tokens, ctx)

    return prefill_step
