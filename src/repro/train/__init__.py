from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]
