"""Serving telemetry plane: metrics registry, span recorder, clocks.

Zero third-party dependencies (importable from lint rules and bare
smoke subprocesses). See DESIGN.md §16 for the plane's invariants:
stats dicts stay the writable source of truth, the registry reads them
at render time, and span recording happens only on the pump thread
through the injectable clock.
"""
from . import schema
from .clock import default_clock, wall_clock
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      escape_label, hist_from_json, parse_exposition)
from .spans import SpanRecorder, write_trace

__all__ = [
    "schema", "default_clock", "wall_clock",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "escape_label", "hist_from_json", "parse_exposition",
    "SpanRecorder", "write_trace",
]
