"""Zero-dependency metrics registry with Prometheus text exposition.

Three metric kinds: ``Counter`` and ``Gauge`` (a float cell), and
``Histogram`` (fixed log-spaced buckets, Prometheus ``le`` semantics:
an observation lands in the first bucket whose upper edge is >= the
value; values above the last edge land in the implicit +Inf overflow
bucket). Histograms merge bucket-wise, which is how bench passes and
per-phase shards combine.

The registry also *adopts* existing plain-dict stats surfaces
(``register_stats``): the dict stays the writable source of truth —
engine/service code keeps doing ``stats["k"] += 1`` and benches keep
doing ``for k in stats: stats[k] = 0`` — and the registry reads the
live values only at render time. That keeps the hot-path cost of the
migration at exactly zero while ``GET /metrics`` covers every key.

Rendering follows the Prometheus text format v0.0.4 (HELP/TYPE per
family, cumulative ``_bucket`` series with escaped label values,
``_sum``/``_count``). ``parse_exposition`` is the matching reader used
by the round-trip tests and the CI smoke.
"""
from __future__ import annotations

import json
import re
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from . import schema

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "escape_label", "parse_exposition"]


def escape_label(value: str) -> str:
    """Escape a label value per the exposition format."""
    return (value.replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(v: float) -> str:
    if isinstance(v, bool):
        return str(int(v))
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_text(labels: Mapping[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{escape_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


@dataclass
class Counter:
    name: str
    help: str
    labels: Dict[str, str] = field(default_factory=dict)
    value: float = 0.0
    kind: str = "counter"

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        self.value += n

    def set(self, v: float) -> None:
        # benches reset stats between passes; a reset is a restart
        self.value = float(v)


@dataclass
class Gauge:
    name: str
    help: str
    labels: Dict[str, str] = field(default_factory=dict)
    value: float = 0.0
    kind: str = "gauge"

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram; ``le`` edges are inclusive upper bounds."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Iterable[float]] = None,
                 labels: Optional[Mapping[str, str]] = None):
        edges = tuple(buckets if buckets is not None
                      else schema.LATENCY_BUCKETS_S)
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError(f"histogram {name}: edges must be strictly "
                             f"increasing, got {edges}")
        self.name, self.help = name, help
        self.labels = dict(labels or {})
        self.edges = edges
        # counts[i] observations in (edges[i-1], edges[i]]; counts[-1]
        # is the +Inf overflow bucket
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def merge(self, other: "Histogram") -> None:
        if other.edges != self.edges:
            raise ValueError(f"histogram {self.name}: cannot merge "
                             f"mismatched edges")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count

    def to_dict(self) -> dict:
        """JSON-friendly form for BENCH payloads (non-cumulative counts;
        counts[-1] is the overflow bucket)."""
        return {"le": list(self.edges), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}

    @classmethod
    def from_dict(cls, d: Mapping, name: str = "hist") -> "Histogram":
        h = cls(name, buckets=d["le"])
        counts = list(d["counts"])
        if len(counts) != len(h.counts):
            raise ValueError(f"histogram {name}: {len(counts)} counts for "
                             f"{len(h.edges)} edges")
        h.counts = counts
        h.sum = float(d.get("sum", 0.0))
        h.count = int(d.get("count", sum(counts)))
        return h

    def quantile(self, q: float) -> float:
        """Bucket-upper-edge quantile (what a Prometheus consumer sees)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        running = 0
        for i, c in enumerate(self.counts):
            running += c
            if running >= target and c:
                return (self.edges[i] if i < len(self.edges)
                        else self.edges[-1])
        return self.edges[-1]


class MetricsRegistry:
    """Holds metric objects plus adopted stats dicts; renders exposition."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], object] = {}
        # (prefix, live dict, {key: (kind, help)})
        self._stats_views: List[Tuple[str, Mapping, Mapping]] = []

    # ------------------------------------------------------ creation
    def _add(self, metric):
        key = (metric.name, tuple(sorted(metric.labels.items())))
        if key in self._metrics:
            raise ValueError(f"duplicate metric {key}")
        self._metrics[key] = metric
        return metric

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._add(Counter(name, help, labels))

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._add(Gauge(name, help, labels))

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None,
                  **labels) -> Histogram:
        return self._add(Histogram(name, help, buckets, labels))

    def register_stats(self, prefix: str, stats: Mapping,
                       declared: Mapping) -> None:
        """Adopt a live stats dict: every present key must be declared
        (kind + help), values are read at render time."""
        undeclared = set(stats) - set(declared)
        if undeclared:
            raise ValueError(f"stats keys {sorted(undeclared)} not in the "
                             f"telemetry schema for prefix {prefix!r}")
        self._stats_views.append((prefix, stats, declared))

    # ----------------------------------------------------- rendering
    def _families(self):
        fams: Dict[str, List] = {}
        helps: Dict[str, Tuple[str, str]] = {}
        for prefix, stats, declared in self._stats_views:
            for key in stats:
                kind, help_ = declared[key]
                name = prefix + key
                helps.setdefault(name, (kind, help_))
                fams.setdefault(name, []).append(
                    Gauge(name, help_, {}, float(stats[key]), kind=kind))
        for metric in self._metrics.values():
            helps.setdefault(metric.name, (metric.kind, metric.help))
            fams.setdefault(metric.name, []).append(metric)
        return fams, helps

    def render(self) -> str:
        """Prometheus text exposition format v0.0.4."""
        out: List[str] = []
        fams, helps = self._families()
        for name in sorted(fams):
            kind, help_ = helps[name]
            out.append(f"# HELP {name} {help_}" if help_
                       else f"# HELP {name} (no help)")
            out.append(f"# TYPE {name} {kind}")
            for m in fams[name]:
                if kind == "histogram":
                    cum = 0
                    for i, edge in enumerate(m.edges):
                        cum += m.counts[i]
                        lbl = dict(m.labels, le=_fmt(edge))
                        out.append(f"{name}_bucket{_labels_text(lbl)} {cum}")
                    cum += m.counts[-1]
                    lbl = dict(m.labels, le="+Inf")
                    out.append(f"{name}_bucket{_labels_text(lbl)} {cum}")
                    out.append(f"{name}_sum{_labels_text(m.labels)} "
                               f"{_fmt(m.sum)}")
                    out.append(f"{name}_count{_labels_text(m.labels)} "
                               f"{m.count}")
                else:
                    out.append(f"{name}{_labels_text(m.labels)} "
                               f"{_fmt(m.value)}")
        return "\n".join(out) + "\n"


# ------------------------------------------------------------- parser
_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)\s*$')
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape(value: str) -> str:
    return re.sub(r'\\(.)',
                  lambda m: {"n": "\n", '"': '"', "\\": "\\"}.get(
                      m.group(1), "\\" + m.group(1)), value)


def parse_exposition(text: str) -> dict:
    """Parse exposition text into {"types": {family: kind},
    "samples": {(name, ((label, value), ...)): float}}. Raises
    ValueError on a line that is neither comment, blank, nor sample —
    the round-trip tests and the CI smoke both lean on that strictness.
    """
    types: Dict[str, str] = {}
    samples: Dict[Tuple[str, Tuple], float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            fam, _, kind = rest.partition(" ")
            types[fam] = kind.strip()
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: not a valid sample: {line!r}")
        labels = []
        if m.group("labels"):
            consumed = 0
            for lm in _LABEL_RE.finditer(m.group("labels")):
                labels.append((lm.group(1), _unescape(lm.group(2))))
                consumed = lm.end()
            rest = m.group("labels")[consumed:].strip(" ,")
            if rest:
                raise ValueError(f"line {lineno}: bad labels {rest!r}")
        raw = m.group("value")
        value = float("inf") if raw == "+Inf" else float(raw)
        samples[(m.group("name"), tuple(labels))] = value
    return {"types": types, "samples": samples}


def hist_from_json(d) -> Optional[Histogram]:
    """Best-effort load of a BENCH-payload histogram dict (None if the
    shape is not a histogram — bench_diff uses this on foreign JSON)."""
    if not isinstance(d, Mapping) or "le" not in d or "counts" not in d:
        return None
    try:
        return Histogram.from_dict(d)
    except (ValueError, TypeError, KeyError):
        return None


def dumps_compact(obj) -> str:
    """Stable compact JSON (shared by the trace writers)."""
    return json.dumps(obj, separators=(",", ":"), sort_keys=True)
