"""Declared metric schema: the single enumeration of every stats key.

Everything that reports a number hangs off this module: the engine and
service ``stats`` dicts stay plain dicts (so ``for k in eng.stats:``,
``dict(svc.stats)``, delta arithmetic, and ``**eng.stats`` splats all
keep working), but every key they are allowed to carry is declared HERE
with a metric kind and help string. The Prometheus exposition
(``metrics.MetricsRegistry.render``), the ``/metrics`` route, the
docs/SERVING.md glossaries, and the ``stats-schema`` AST-lint rule all
read this enumeration — adding a stats key without declaring it is a
lint failure, not a silent divergence.

Zero dependencies by design: ``repro.analysis.astlint`` imports this at
lint time, and scripts/http_smoke.py imports it from a bare subprocess.
"""
from __future__ import annotations

import math

# ------------------------------------------------------------- buckets
def log_buckets(lo: float, hi: float, per_decade: int = 4) -> tuple:
    """Fixed log-spaced histogram bucket edges from lo to hi inclusive."""
    if not (lo > 0 and hi > lo and per_decade > 0):
        raise ValueError(f"bad bucket spec lo={lo} hi={hi}/{per_decade}")
    n = int(round((math.log10(hi) - math.log10(lo)) * per_decade))
    return tuple(round(10.0 ** (math.log10(lo) + i / per_decade), 12)
                 for i in range(n + 1))


# per-step phase times: sub-microsecond Python overhead up to multi-second
# faulted steps; request latencies: 1 ms to 100 s covers smoke -> overload
PHASE_BUCKETS_S = log_buckets(1e-6, 10.0, per_decade=4)
LATENCY_BUCKETS_S = log_buckets(1e-3, 100.0, per_decade=4)

# ------------------------------------------------- stats declarations
# kind: "counter" = monotone within a run (benches zero them between
# passes — that is a restart, same as a process restart in Prometheus
# terms); "gauge" = point-in-time or high-water value.
ENGINE_STATS = {
    "prefill_ticks": ("counter", "scheduler ticks that dispatched a prefill chunk"),
    "decode_ticks": ("counter", "scheduler ticks that dispatched a batched decode scan"),
    "decode_slot_steps": ("counter", "slot-steps that emitted a token across decode scans"),
    "prefill_tokens": ("counter", "prompt tokens consumed by prefill chunks"),
    "host_syncs": ("counter", "host synchronisation points (one per scan/chunk tail)"),
    "device_steps": ("counter", "device-side model steps (scan length x dispatches)"),
    "drafted_tokens": ("counter", "tokens drafted (speculative) or scanned (plain decode)"),
    "accepted_tokens": ("counter", "tokens accepted/emitted to requests"),
    "prefix_hits": ("counter", "prompts that reused a cached shared prefix"),
    "prefix_hit_tokens": ("counter", "prompt tokens served from the prefix cache"),
    "bytes_saved": ("counter", "KV bytes not written thanks to prefix reuse"),
    "cow_copies": ("counter", "copy-on-write page copies"),
    "pages_in_use": ("gauge", "KV pages currently allocated"),
    "pages_peak": ("gauge", "high-water mark of allocated KV pages"),
    "cancelled": ("counter", "requests cancelled (client or deadline)"),
    "faults": ("counter", "faults absorbed by the engine fault boundary"),
    "kv_bytes_peak": ("gauge", "high-water mark of KV arena bytes"),
}

SERVICE_STATS = {
    "submitted": ("counter", "requests accepted into the service"),
    "completed": ("counter", "requests finished with a token-bearing result"),
    "shed": ("counter", "requests rejected at admission (queue full or infeasible)"),
    "shed_infeasible": ("counter", "sheds attributed to the feasibility predictor"),
    "expired": ("counter", "admitted requests evicted at their deadline"),
    "cancelled": ("counter", "requests cancelled by the client"),
    "faults": ("counter", "engine faults observed by the service boundary"),
    "queue_peak": ("gauge", "high-water mark of the waiting queue"),
}

# every stats key any serving/ module may write; the stats-schema lint
# rule rejects writes outside this set
DECLARED_STAT_KEYS = frozenset(ENGINE_STATS) | frozenset(SERVICE_STATS)

ENGINE_PREFIX = "repro_engine_"
SERVICE_PREFIX = "repro_service_"

# ------------------------------------------------- span phase names
# per-step wall-time attribution (engine.last_step["phases"]) and the
# histogram label values under repro_step_phase_seconds{phase=...}
PHASES = ("admit", "prefill_dispatch", "decode_scan", "host_sync",
          "token_fanout", "total")

# span names the recorder may emit per request track (docs/SERVING.md
# "Observability" documents each)
SPAN_NAMES = ("request", "queued", "active", "prefill", "decode", "spec")
INSTANT_NAMES = ("first_token", "finish", "shed")
TERMINAL_REASONS = ("length", "eos", "error", "cancelled", "shed")

PHASE_HISTOGRAM = "repro_step_phase_seconds"
TTFT_HISTOGRAM = "repro_request_ttft_seconds"
LATENCY_HISTOGRAM = "repro_request_latency_seconds"


def metric_names() -> list:
    """Every family name the default registry exposes (smoke checks)."""
    names = [ENGINE_PREFIX + k for k in ENGINE_STATS]
    names += [SERVICE_PREFIX + k for k in SERVICE_STATS]
    names += [PHASE_HISTOGRAM, TTFT_HISTOGRAM, LATENCY_HISTOGRAM]
    return names
