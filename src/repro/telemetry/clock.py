"""The two clocks of the serving plane, named.

``default_clock`` is the injectable monotonic clock every serving
component takes as a constructor parameter — tests replace it with a
fake, and the ``no-raw-clock`` AST rule keeps serving modules from
reading ``time.*`` directly once they declare the parameter.

``wall_clock`` is the deliberate exception: watchdog heartbeats. A
watchdog that beats on the injectable clock is useless — a frozen fake
clock (or a wedged pump that stops advancing its own clock) would mask
the exact hang the watchdog exists to catch. Routing all heartbeat
reads through this ONE helper replaces the per-site
``# repro-lint: disable=no-raw-clock`` escapes that used to annotate
each read in ``serving/service.py``; this module declares no ``clock``
parameter, so the rule does not apply here, and the escape hatch count
in serving/ drops to zero.
"""
from __future__ import annotations

import time

__all__ = ["default_clock", "wall_clock"]

default_clock = time.monotonic


def wall_clock() -> float:
    """Raw wall-clock read for watchdog heartbeats ONLY (see module
    docstring); everything else must use an injected clock."""
    return time.monotonic()
