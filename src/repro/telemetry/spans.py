"""Per-request span recorder and trace exporters.

The recorder is a passive sink: it NEVER reads a clock. Every record
method takes explicit timestamps measured by the caller (the engine's
injectable ``clock``), so the pump-thread-only discipline and the
no-raw-clock lint both hold by construction — there is exactly one
component that decides what time it is, and it is injected.

Granularity is the host sync: the engine learns what happened (which
slots emitted, what was accepted) only when it harvests a scan or a
prefill tail, so spans are recorded at those points with the
timestamps taken around the dispatch. Per request the track is:

  request   submit -> terminal            (top-level envelope)
  queued    submit -> admit               (waiting for a slot)
  active    admit  -> terminal            (holding a slot)
  prefill   one span per chunk            (args: lo, hi, tokens)
  decode    one span per scan the slot    (args: tokens, k_steps)
            participated in
  spec      one span per speculative      (args: tokens, drafted,
            cycle                          accepted, k, cycles)
  first_token / finish instants           (finish args: reason,
                                           n_tokens, pages_held)

``queued + active`` therefore tiles ``request`` exactly — the
trace-export smoke asserts that coverage within 5% and that per-track
spans never overlap. Sheds happen before a uid exists, so they are
engine-track instants with a shed counter, not request tracks.

Exports: Chrome trace-event JSON (load via Perfetto -> "Open trace
file") and a flat JSONL stream, one record per line.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .metrics import dumps_compact

__all__ = ["SpanRecorder"]

# Chrome tids: 0 is the engine/step track; request uid u maps to u + 1
_ENGINE_TID = 0


class SpanRecorder:
    def __init__(self):
        # flat event log: dicts with type "span" | "instant"
        self.records: List[dict] = []
        # uid -> {"t_submit", "t_admit", "prompt_len", "tokens"}
        self._live: Dict[int, dict] = {}
        # uid -> terminal reason (exactly-one-terminal bookkeeping)
        self.terminals: Dict[int, str] = {}
        self.sheds = 0

    # ------------------------------------------------------ lifecycle
    def submit(self, uid: int, t: float, prompt_len: int) -> None:
        self._live[uid] = {"t_submit": t, "t_admit": None,
                           "prompt_len": prompt_len, "tokens": 0}

    def admit(self, uid: int, t: float, slot: int) -> None:
        info = self._live.get(uid)
        if info is not None:
            info["t_admit"] = t
            info["slot"] = slot

    def span(self, name: str, uid: Optional[int], t0: float, t1: float,
             **args) -> None:
        """A completed slice (prefill chunk, decode scan, spec cycle,
        or an engine-track step phase when uid is None)."""
        info = self._live.get(uid) if uid is not None else None
        if info is not None:
            info["tokens"] += int(args.get("tokens", 0))
        self.records.append({"type": "span", "name": name, "uid": uid,
                             "t0": t0, "t1": t1, "args": args})

    def instant(self, name: str, uid: Optional[int], t: float,
                **args) -> None:
        self.records.append({"type": "instant", "name": name, "uid": uid,
                             "t": t, "args": args})

    def first_token(self, uid: int, t: float) -> None:
        self.instant("first_token", uid, t)

    def finish(self, uid: int, t: float, reason: str,
               n_tokens: int = 0, pages_held: int = 0) -> None:
        """Terminal for a submitted uid; emits the envelope spans."""
        info = self._live.pop(uid, None)
        if info is None:
            # unknown or already-terminal uid: record the anomaly (the
            # lifecycle tests assert exactly one terminal per uid) but
            # never throw on the pump thread
            self.terminals.setdefault(uid, reason)
            self.instant("finish", uid, t, reason=reason,
                         n_tokens=n_tokens, duplicate=True)
            return
        self.terminals[uid] = reason
        t_submit, t_admit = info["t_submit"], info["t_admit"]
        # uid already popped from _live, so these envelope spans do not
        # double-count into the per-request token tally
        self.span("request", uid, t_submit, t,
                  prompt_len=info["prompt_len"])
        if t_admit is not None:
            self.span("queued", uid, t_submit, t_admit)
            self.span("active", uid, t_admit, t)
        else:
            # cancelled/evicted while still waiting: queued covers all
            self.span("queued", uid, t_submit, t)
        self.instant("finish", uid, t, reason=reason, n_tokens=n_tokens,
                     pages_held=pages_held, span_tokens=info["tokens"])

    def shed(self, t: float, reason: str) -> None:
        self.sheds += 1
        self.instant("shed", None, t, reason=reason)

    # -------------------------------------------------------- exports
    def _tid(self, rec: dict) -> int:
        uid = rec.get("uid")
        return _ENGINE_TID if uid is None else int(uid) + 1

    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON; microsecond timestamps, one thread
        per request plus thread 0 for engine step phases."""
        events = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                   "args": {"name": "repro-serving"}},
                  {"name": "thread_name", "ph": "M", "pid": 0,
                   "tid": _ENGINE_TID, "args": {"name": "engine"}}]
        named = set()
        for rec in self.records:
            tid = self._tid(rec)
            if tid != _ENGINE_TID and tid not in named:
                named.add(tid)
                events.append({"name": "thread_name", "ph": "M", "pid": 0,
                               "tid": tid,
                               "args": {"name": f"req {tid - 1}"}})
            args = dict(rec["args"])
            if rec.get("uid") is not None:
                args["uid"] = rec["uid"]
            if rec["type"] == "span":
                events.append({"name": rec["name"], "ph": "X", "pid": 0,
                               "tid": tid, "cat": "serving",
                               "ts": rec["t0"] * 1e6,
                               "dur": max(0.0, (rec["t1"] - rec["t0"]) * 1e6),
                               "args": args})
            else:
                events.append({"name": rec["name"], "ph": "i", "s": "t",
                               "pid": 0, "tid": tid, "cat": "serving",
                               "ts": rec["t"] * 1e6, "args": args})
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def to_jsonl(self) -> str:
        return "".join(dumps_compact(rec) + "\n" for rec in self.records)

    # ------------------------------------------------------- analysis
    def open_uids(self) -> list:
        """Submitted uids with no terminal yet (drain checks)."""
        return sorted(self._live)


def write_trace(trace_dir, recorder: SpanRecorder) -> tuple:
    """Write trace.json (Chrome/Perfetto) + spans.jsonl under trace_dir;
    returns the two paths."""
    import pathlib

    d = pathlib.Path(trace_dir)
    d.mkdir(parents=True, exist_ok=True)
    trace_path = d / "trace.json"
    jsonl_path = d / "spans.jsonl"
    trace_path.write_text(dumps_compact(recorder.to_chrome_trace()))
    jsonl_path.write_text(recorder.to_jsonl())
    return trace_path, jsonl_path
