"""Pallas TPU kernel: length-aware split-KV decode attention (flash-decoding).

One query token per slot attends a slotted KV cache laid out (B, S, Hkv, hd).
Grid (B, Hkv, S/bk) with the KV-sequence axis innermost: the online-softmax
accumulators (m, l, acc) live in VMEM scratch across the KV loop, exactly like
``flash_attention.py`` — but causality here is *per slot*: each batch row
carries its own visible limit ``start`` (the absolute position of the query),
and every KV block strictly beyond that limit is skipped via ``pl.when``, so
a slot that is 40 tokens into a 4096-slot cache issues work for one block,
not thirty-two. That block skip is what makes decode cost track *actual*
sequence length instead of cache capacity.

INT8 KV path: ``k``/``v`` arrive as int8 with per-(pos, head) f32 scales. The
dequant is fused into the epilogue — scores are scaled by ``k_s`` after the
QK^T dot and probabilities by ``v_s`` before the PV dot — so the cache is
only ever read as int8 (half the HBM stream of bf16) and no dequantized KV
tile is ever materialized. The ``l`` normalizer accumulates the *unscaled*
probabilities: out = (Σ p·v_s·v) / (Σ p) == softmax(s)·v_s·v, matching the
XLA fallback's probability-side dequant bit-for-tolerance.

GQA: the G = Hq/Hkv query heads sharing one KV head form the row axis of
every score tile, so the kernel's dots are (G, hd)x(hd, bk) and (G, bk)x(bk,
hd) — the KV block is read once per group, not once per query head.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kv_layout import (CompilerParams as _CompilerParams,
                                     NEG_INF, from_store, pad_kv_blocks,
                                     transpose_scales)


def _body(start, q_ref, k_ref, v_ref, rest, *, bk: int, n_kv: int,
          scale: float, quantized: bool):
    """Shared online-softmax body. ``start`` is this row's query position
    (already read from whichever ref layout the wrapper uses); the KV refs
    hold one bk-long block of LOGICAL positions j*bk..(j+1)*bk-1 — the
    contiguous wrapper blocks a (B, S, Hkv, hd) cache, the paged wrapper a
    (n_pages, page_size, Hkv, hd) arena with bk == page_size and the block
    index taken from the page table, and the body cannot tell the
    difference (same block shapes, same logical positions)."""
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * bk <= start)                     # block intersects the window
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (G, hd)
        # int8 reads as-is (dequant on scores); uint16 paged-arena blocks
        # bitcast back to bf16 (from_store) before the f32 upcast
        k = from_store(k_ref[0, :, 0]).astype(jnp.float32)    # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if quantized:
            s = s * ks_ref[0, 0][None, :]         # dequant on scores, not KV
        kv_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kv_pos <= start, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        if quantized:
            p = p * vs_ref[0, 0][None, :]         # dequant on probabilities
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, from_store(v_ref[0, :, 0]).astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _kernel(start_ref, q_ref, k_ref, v_ref, *rest, bk: int, n_kv: int,
            scale: float, quantized: bool):
    _body(start_ref[0, 0], q_ref, k_ref, v_ref, rest, bk=bk, n_kv=n_kv,
          scale=scale, quantized=quantized)


def _paged_kernel(tbl_ref, start_ref, q_ref, k_ref, v_ref, *rest, bk: int,
                  n_kv: int, scale: float, quantized: bool):
    # tbl_ref/start_ref are SMEM scalar-prefetch refs: the table drives the
    # BlockSpec index maps (never read here), start indexes by batch row
    _body(start_ref[pl.program_id(0)], q_ref, k_ref, v_ref, rest, bk=bk,
          n_kv=n_kv, scale=scale, quantized=quantized)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            k_s: Optional[jax.Array] = None,
                            v_s: Optional[jax.Array] = None,
                            start: jax.Array = None, *, bk: int = 128,
                            interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); k/v: (B, S, Hkv, hd) float or int8 (then k_s/v_s
    (B, S, Hkv) f32 scales); start: (B,) int32 per-slot query positions.
    Returns (B, Hq, hd) bf16."""
    b, hq, hd = q.shape
    s_len, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bk = min(bk, s_len)
    k, v, k_s, v_s, n_kv = pad_kv_blocks(k, v, k_s, v_s, bk)
    quantized = k_s is not None

    inputs = [jnp.reshape(start, (b, 1)).astype(jnp.int32),
              q.reshape(b, hkv, g, hd), k, v]
    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, h, j: (bb, 0)),
        pl.BlockSpec((1, 1, g, hd), lambda bb, h, j: (bb, h, 0, 0)),
        pl.BlockSpec((1, bk, 1, hd), lambda bb, h, j: (bb, j, h, 0)),
        pl.BlockSpec((1, bk, 1, hd), lambda bb, h, j: (bb, j, h, 0)),
    ]
    if quantized:
        inputs += list(transpose_scales(k_s, v_s))
        in_specs += [pl.BlockSpec((1, 1, bk), lambda bb, h, j: (bb, h, j)),
                     pl.BlockSpec((1, 1, bk), lambda bb, h, j: (bb, h, j))]

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, n_kv=n_kv, scale=hd ** -0.5,
                          quantized=quantized),
        grid=(b, hkv, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, h, j: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    return out.reshape(b, hq, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                                  k_s: Optional[jax.Array] = None,
                                  v_s: Optional[jax.Array] = None,
                                  start: jax.Array = None,
                                  pages: jax.Array = None, *,
                                  interpret: bool = False) -> jax.Array:
    """Page-table-indirect split-KV decode: q (B, Hq, hd) vs a PAGED arena.

    k/v: (n_pages, page_size, Hkv, hd) float or int8 (then k_s/v_s
    (n_pages, page_size, Hkv) f32 scales); start: (B,) int32; pages:
    (B, n_blk) int32 — the window prefix of each row's page table. The KV
    block size is pinned to ``page_size``, so grid step (b, h, j) DMAs
    physical page ``pages[b, j]`` via a scalar-prefetch index map — same
    body, block shapes, and logical-position skip/mask as the contiguous
    kernel, only the block index indirects. Unallocated table entries point
    at physical page 0 (the trash page) and sit beyond every causal limit.
    Returns (B, Hq, hd) bf16."""
    b, hq, hd = q.shape
    ps, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    n_blk = pages.shape[1]
    quantized = k_s is not None

    inputs = [q.reshape(b, hkv, g, hd), k, v]
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bb, h, j, tbl, st: (bb, h, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda bb, h, j, tbl, st: (tbl[bb, j], 0, h, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda bb, h, j, tbl, st: (tbl[bb, j], 0, h, 0)),
    ]
    if quantized:
        inputs += list(transpose_scales(k_s, v_s))   # (n_pages, Hkv, ps)
        in_specs += [pl.BlockSpec((1, 1, ps),
                                  lambda bb, h, j, tbl, st: (tbl[bb, j], h, 0))
                     ] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_blk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bb, h, j, tbl, st: (bb, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bk=ps, n_kv=n_blk,
                          scale=hd ** -0.5, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), jnp.bfloat16),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pages.astype(jnp.int32),
      jnp.asarray(start, jnp.int32).reshape(b), *inputs)
    return out.reshape(b, hq, hd)
