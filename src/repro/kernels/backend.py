"""Execution-backend registry for the quantized/attention hot paths.

Three registered backends (DESIGN.md §Backend-registry):

  pallas — the TPU Pallas kernels (fused W8A8 epilogue, flash attention)
  xla    — portable jnp implementations (``kernels.ref``) that XLA fuses;
           the default off-TPU and the correctness oracle everywhere
  ref    — the Pallas kernels in interpret mode: exercises the real kernel
           logic (grids, padding, epilogues) on any platform, for tests

Selection order: explicit ``set_backend()`` > ``REPRO_BACKEND`` env var >
platform default (pallas on TPU, xla elsewhere). Backends expose a uniform
primitive surface; ``kernels.ops`` owns the shape plumbing (flattening
leading axes, dynamic activation quant) and dispatches here — model code
never imports a kernel module directly.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Backend:
    """Primitive surface each backend implements.

    quantize_rowwise: (M, K) float -> ((M, K) int8, (M,) f32 scales)
    int8_matmul:      (M, K) int8, (K, N) int8, (M,) f32, (N,) f32 -> (M, N)
    flash_attention:  (B, S, H, hd) q/k/v -> (B, S, H, hd), causal
    decode_attention: (B, Hq, hd) q vs a (B, W, Hkv, hd) slotted KV window
                      (float, or int8 + (B, W, Hkv) f32 scales), (B,) int32
                      per-slot ``start`` -> (B, Hq, hd); the serving decode
                      hot path (split-KV flash decoding on pallas)
    prefill_attention: (B, Sq, Hq, hd) q at absolute positions
                      start..start+Sq-1 vs the same slotted KV window
                      -> (B, Sq, Hq, hd); the serving chunked-prefill hot
                      path (cache-continuation online-softmax kernel on
                      pallas; on xla it IS ``cached_attention_ref`` — the
                      token-identity hinge, exactly how ``decode_attention``
                      landed)
    decode_attention_paged / prefill_attention_paged:
                      the same two primitives against a PAGED KV arena —
                      k/v (n_pages, page_size, Hkv, hd) (scales
                      (n_pages, page_size, Hkv)) plus a (B, n_blk) int32
                      ``pages`` window prefix of each row's page table. On
                      xla: gather-to-contiguous + the contiguous einsum
                      (bit-identity with the contiguous layout by
                      construction); on pallas/ref: the block index maps
                      walk the table via scalar prefetch, no gather ever
                      materializes (DESIGN.md §12)
    """
    name: str
    quantize_rowwise: Callable
    int8_matmul: Callable
    flash_attention: Callable
    decode_attention: Callable
    prefill_attention: Callable
    decode_attention_paged: Callable
    prefill_attention_paged: Callable


_REGISTRY: Dict[str, Backend] = {}
_ACTIVE: Optional[str] = None


def register(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def available() -> tuple:
    return tuple(sorted(_REGISTRY))


def set_backend(name: Optional[str]) -> Optional[str]:
    """Force a backend globally (None = back to auto). Returns the previous
    forced value so tests can restore it.

    Trace-time contract: the backend is resolved when a function is TRACED,
    so functions already jit-compiled keep the backend they were traced
    with — switching here affects new traces only. Flip the backend before
    building jitted steps (or clear jax caches) when A/B-ing backends."""
    global _ACTIVE
    if name is not None and name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; available: {available()}")
    prev, _ACTIVE = _ACTIVE, name
    return prev


def get_backend(name: Optional[str] = None) -> Backend:
    name = (name or _ACTIVE or os.environ.get("REPRO_BACKEND")
            or ("pallas" if jax.default_backend() == "tpu" else "xla"))
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; available: {available()}")
    return _REGISTRY[name]


# ------------------------------------------------------------------ xla
def _xla_backend() -> Backend:
    from repro.kernels import ref
    return Backend(
        name="xla",
        quantize_rowwise=lambda x: ref.quantize_ref(x, axis=-1),
        int8_matmul=lambda x_q, w_q, x_s, w_s: ref.int8_matmul_ref(
            x_q, w_q, w_s, x_s),
        flash_attention=lambda q, k, v: ref.flash_attention_ref(
            q, k, v, causal=True),
        decode_attention=ref.decode_attention_ref,
        # verbatim the masked einsum: serial prefill, chunked engine prefill,
        # and the Sq=1 decode slice all share one set of numerics bit-for-bit
        prefill_attention=ref.cached_attention_ref,
        decode_attention_paged=ref.paged_decode_attention_ref,
        prefill_attention_paged=ref.paged_prefill_attention_ref,
    )


# ------------------------------------------------------------------ pallas
def _fold_heads(fn):
    """(B, S, H, hd) <-> (B*H, S, hd) adapter around the Pallas flash kernel
    (equal q/kv heads; GQA folded by the caller)."""
    def wrapped(q, k, v):
        b, s, h, hd = q.shape
        fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, s, hd)
        o = fn(fold(q), fold(k), fold(v))
        return jnp.moveaxis(o.reshape(b, h, s, hd), 1, 2)
    return wrapped


def _pallas_backend(interpret: bool) -> Backend:
    from repro.kernels.decode_attention import (decode_attention_pallas,
                                                paged_decode_attention_pallas)
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.int8_matmul import int8_matmul_pallas
    from repro.kernels.prefill_attention import (
        paged_prefill_attention_pallas, prefill_attention_pallas)
    from repro.kernels.quantize import quantize_rowwise_pallas
    return Backend(
        name="ref" if interpret else "pallas",
        quantize_rowwise=lambda x: quantize_rowwise_pallas(
            x, interpret=interpret),
        int8_matmul=lambda x_q, w_q, x_s, w_s: int8_matmul_pallas(
            x_q, w_q, x_s, w_s, interpret=interpret),
        flash_attention=_fold_heads(lambda q, k, v: flash_attention_pallas(
            q, k, v, interpret=interpret)),
        decode_attention=lambda q, k, v, k_s, v_s, start:
            decode_attention_pallas(q, k, v, k_s, v_s, start,
                                    interpret=interpret),
        prefill_attention=lambda q, k, v, k_s, v_s, start:
            prefill_attention_pallas(q, k, v, k_s, v_s, start,
                                     interpret=interpret),
        decode_attention_paged=lambda q, k, v, k_s, v_s, start, pages:
            paged_decode_attention_pallas(q, k, v, k_s, v_s, start, pages,
                                          interpret=interpret),
        prefill_attention_paged=lambda q, k, v, k_s, v_s, start, pages:
            paged_prefill_attention_pallas(q, k, v, k_s, v_s, start, pages,
                                           interpret=interpret),
    )


register(_xla_backend())
register(_pallas_backend(interpret=False))
register(_pallas_backend(interpret=True))
