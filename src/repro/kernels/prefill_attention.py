"""Pallas TPU kernel: fused chunked-prefill (cache-continuation) attention.

The Sq>1 generalization of ``decode_attention.py``: a chunk of Sq query
tokens per slot attends the slotted KV cache laid out (B, W, Hkv, hd), where
W is the static visible window the caller already sliced. Grid
(B, Hkv, Sq/bq, W/bk) with the KV-sequence axis innermost: the online-softmax
accumulators (m, l, acc) live in VMEM scratch across the KV loop per query
tile, so no (B, Sq, Hkv, G, W) score tensor is ever materialized — the
masked-einsum prefill this replaces was the engine's TTFT bottleneck
precisely because it built that tensor per chunk.

Causality is *absolute*, per slot: each batch row carries ``start`` (the
chunk's first absolute position) and query i of the chunk sees exactly cache
positions <= start + i. Because the limit depends only on the query's
absolute position — never on the chunk boundaries, the query-tile size, or
the window bucket — chunk N of a prompt attends chunks 0..N with the same
per-row arithmetic as a whole-prompt prefill: KV blocks fully beyond a row's
limit contribute exact no-ops (p == +0.0, corr == 1.0) when visited and are
skipped entirely via ``pl.when`` when the whole tile is past them, so chunked
and whole-prompt prefill are *bit-consistent* row for row.

INT8 KV path: identical epilogue placement to the decode kernel — ``k``/``v``
are read as int8, per-(pos, head) ``k_s`` scales the score tile after QK^T,
``v_s`` scales the probability tile before PV, and the ``l`` normalizer
accumulates unscaled probabilities. No dequantized KV tile ever exists.

GQA: the G = Hq/Hkv query heads sharing a KV head are folded into the query
tile's row axis — dots are (bq*G, hd)x(hd, bk) and (bq*G, bk)x(bk, hd), one
KV block read per group per tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kv_layout import (CompilerParams as _CompilerParams,
                                     NEG_INF, from_store, pad_kv_blocks,
                                     transpose_scales)


def _body(start, q_ref, k_ref, v_ref, rest, *, bq: int, bk: int, g: int,
          n_kv: int, scale: float, quantized: bool):
    """Shared online-softmax body; ``start`` is this row's chunk-start
    position, already read by the wrapper. KV refs hold one bk-long block
    of LOGICAL positions j*bk..(j+1)*bk-1 — contiguous blocking or a paged
    arena with bk == page_size and the block index from the page table; the
    body is layout-blind (see ``decode_attention._body``)."""
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip KV blocks past the tile's deepest row (absolute causal limit of
    # query i*bq + bq - 1); blocks partially beyond a row's own limit are
    # exact no-ops for that row via the position mask below
    @pl.when(j * bk <= start + (i + 1) * bq - 1)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(bq * g, -1)
        # int8 reads as-is (dequant on scores); uint16 paged-arena blocks
        # bitcast back to bf16 (from_store) before the f32 upcast
        k = from_store(k_ref[0, :, 0]).astype(jnp.float32)    # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if quantized:
            s = s * ks_ref[0, 0][None, :]         # dequant on scores, not KV
        # row r of the tile is query (i*bq + r//g) at absolute position
        # start + i*bq + r//g; 3-D iota then reshape avoids an integer div
        q_pos = (start + i * bq
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, g, bk), 0
                                            ).reshape(bq * g, bk))
        kv_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq * g, bk), 1)
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        if quantized:
            p = p * vs_ref[0, 0][None, :]         # dequant on probabilities
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, from_store(v_ref[0, :, 0]).astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, :, 0] = (acc_ref[...]
                          / jnp.maximum(l_ref[...], 1e-30)[:, None]
                          ).reshape(bq, g, acc_ref.shape[-1]
                                    ).astype(o_ref.dtype)


def _kernel(start_ref, q_ref, k_ref, v_ref, *rest, bq: int, bk: int, g: int,
            n_kv: int, scale: float, quantized: bool):
    _body(start_ref[0, 0], q_ref, k_ref, v_ref, rest, bq=bq, bk=bk, g=g,
          n_kv=n_kv, scale=scale, quantized=quantized)


def _paged_kernel(tbl_ref, start_ref, q_ref, k_ref, v_ref, *rest, bq: int,
                  bk: int, g: int, n_kv: int, scale: float, quantized: bool):
    # tbl_ref/start_ref are SMEM scalar-prefetch refs: the table drives the
    # BlockSpec index maps (never read here), start indexes by batch row
    _body(start_ref[pl.program_id(0)], q_ref, k_ref, v_ref, rest, bq=bq,
          bk=bk, g=g, n_kv=n_kv, scale=scale, quantized=quantized)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def prefill_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                             k_s: Optional[jax.Array] = None,
                             v_s: Optional[jax.Array] = None,
                             start: jax.Array = None, *, bq: int = 16,
                             bk: int = 128,
                             interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, hd) queries at absolute positions start..start+Sq-1;
    k/v: (B, W, Hkv, hd) float or int8 (then k_s/v_s (B, W, Hkv) f32 scales);
    start: (B,) int32 per-slot chunk-start positions. Callers guarantee
    ``W >= start + Sq`` for every row whose output is consumed. Returns
    (B, Sq, Hq, hd) bf16."""
    b, sq, hq, hd = q.shape
    w, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(bq, sq)
    pq = (-sq) % bq                          # ragged chunk: padded query tail
    if pq:                                   # rows are sliced off the output
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    n_q = (sq + pq) // bq
    bk = min(bk, w)
    k, v, k_s, v_s, n_kv = pad_kv_blocks(k, v, k_s, v_s, bk)
    quantized = k_s is not None

    inputs = [jnp.reshape(start, (b, 1)).astype(jnp.int32),
              q.reshape(b, sq + pq, hkv, g, hd), k, v]
    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, h, i, j: (bb, 0)),
        pl.BlockSpec((1, bq, 1, g, hd), lambda bb, h, i, j: (bb, i, h, 0, 0)),
        pl.BlockSpec((1, bk, 1, hd), lambda bb, h, i, j: (bb, j, h, 0)),
        pl.BlockSpec((1, bk, 1, hd), lambda bb, h, i, j: (bb, j, h, 0)),
    ]
    if quantized:
        inputs += list(transpose_scales(k_s, v_s))
        in_specs += [pl.BlockSpec((1, 1, bk), lambda bb, h, i, j: (bb, h, j)),
                     pl.BlockSpec((1, 1, bk), lambda bb, h, i, j: (bb, h, j))]

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, g=g, n_kv=n_kv,
                          scale=hd ** -0.5, quantized=quantized),
        grid=(b, hkv, n_q, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, g, hd),
                               lambda bb, h, i, j: (bb, i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq + pq, hkv, g, hd),
                                       jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bq * g,), jnp.float32),
                        pltpu.VMEM((bq * g,), jnp.float32),
                        pltpu.VMEM((bq * g, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*inputs)
    out = out.reshape(b, sq + pq, hq, hd)
    return out[:, :sq] if pq else out


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def paged_prefill_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                                   k_s: Optional[jax.Array] = None,
                                   v_s: Optional[jax.Array] = None,
                                   start: jax.Array = None,
                                   pages: jax.Array = None, *, bq: int = 16,
                                   interpret: bool = False) -> jax.Array:
    """Page-table-indirect chunked prefill: q (B, Sq, Hq, hd) vs a PAGED
    arena (see ``paged_decode_attention_pallas`` for the layout). The KV
    block size is pinned to ``page_size``; grid step (b, h, i, j) DMAs
    physical page ``pages[b, j]`` via a scalar-prefetch index map. Ragged
    query-tail padding is unchanged from the contiguous wrapper. Returns
    (B, Sq, Hq, hd) bf16."""
    b, sq, hq, hd = q.shape
    ps, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(bq, sq)
    pq = (-sq) % bq                          # ragged chunk: padded query tail
    if pq:                                   # rows are sliced off the output
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    n_q = (sq + pq) // bq
    n_blk = pages.shape[1]
    quantized = k_s is not None

    inputs = [q.reshape(b, sq + pq, hkv, g, hd), k, v]
    in_specs = [
        pl.BlockSpec((1, bq, 1, g, hd),
                     lambda bb, h, i, j, tbl, st: (bb, i, h, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda bb, h, i, j, tbl, st: (tbl[bb, j], 0, h, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda bb, h, i, j, tbl, st: (tbl[bb, j], 0, h, 0)),
    ]
    if quantized:
        inputs += list(transpose_scales(k_s, v_s))   # (n_pages, Hkv, ps)
        in_specs += [
            pl.BlockSpec((1, 1, ps),
                         lambda bb, h, i, j, tbl, st: (tbl[bb, j], h, 0))] * 2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_q, n_blk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, g, hd),
                               lambda bb, h, i, j, tbl, st: (bb, i, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bq * g,), jnp.float32),
                        pltpu.VMEM((bq * g,), jnp.float32),
                        pltpu.VMEM((bq * g, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bq=bq, bk=ps, g=g, n_kv=n_blk,
                          scale=hd ** -0.5, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq + pq, hkv, g, hd),
                                       jnp.bfloat16),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(pages.astype(jnp.int32),
      jnp.asarray(start, jnp.int32).reshape(b), *inputs)
    out = out.reshape(b, sq + pq, hq, hd)
    return out[:, :sq] if pq else out
