"""Pallas TPU kernel: rowwise symmetric INT8 quantization (activation quant).

One pass: read a (bm, K) bf16 tile, compute the row absmax in VMEM, write the
int8 tile + f32 row scales. Fusing quantization this way keeps activation
quant a single HBM round-trip (read 2B/elt, write 1B/elt) in front of the
W8A8 matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale[:, None]), -127, 127
                          ).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def quantize_rowwise_pallas(x: jax.Array, *, bm: int = 256,
                            interpret: bool = False):
    """x: (M, K) float -> ((M, K) int8, (M,) f32 scales)."""
    m, k = x.shape
    bm = min(bm, m)
    pm = (-m) % bm
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
    mp = m + pm
    q, s = pl.pallas_call(
        _kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((mp, k), jnp.int8),
                   jax.ShapeDtypeStruct((mp,), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:m], s[:m]
