"""Pallas TPU kernel: fused W8A8 matmul with per-channel dequant epilogue.

HBM traffic: activations int8 (pre-quantized or quantized on the fly by the
caller via ``kernels.quantize``), weights int8, output bf16 — the weight
stream halves vs bf16 and the MXU runs in its int8 mode (v5e: 394 TOPS vs 197
TFLOPS). Accumulation is int32 in a VMEM scratch tile; the f32 dequant
(row-scale x col-scale) happens once per output tile in the epilogue — the
dequantized weight matrix is never materialized anywhere.

Grid: (M/bm, N/bn, K/bk), K innermost so the accumulator tile lives in VMEM
across the K loop. Block sizes default to MXU-aligned (128) multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions (TPUCompilerParams -> CompilerParams)
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        scale = xs_ref[...][:, None] * ws_ref[...][None, :]
        o_ref[...] = (acc * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_pallas(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                       w_scale: jax.Array, *, bm: int = 256, bn: int = 256,
                       bk: int = 512, interpret: bool = False) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (M,); w_scale: (N,)."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x_q = jnp.pad(x_q, ((0, pm), (0, pk)))
        x_scale = jnp.pad(x_scale, (0, pm))
    if pk or pn:
        w_q = jnp.pad(w_q, ((0, pk), (0, pn)))
        w_scale = jnp.pad(w_scale, (0, pn))
    mp, kp, np_ = m + pm, k + pk, n + pn
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
    return out[:m, :n]
