"""Pallas TPU kernel: causal flash attention (online softmax, block-skipping).

Grid (B*H, Sq/bq, Skv/bk) with the KV dimension innermost; the (m, l, acc)
accumulators live in VMEM scratch across the KV loop. Causality is exploited
structurally: KV blocks strictly above the diagonal contribute nothing and are
skipped via ``pl.when`` — on TPU the grid still visits them, but no MXU work
or HBM traffic for the block is issued (unlike the XLA path, which multiplies
the masked half anyway). GQA is handled by the caller (q heads grouped).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions (TPUCompilerParams -> CompilerParams)
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_kv: int, scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki * bk <= qi * bq + bq - 1)          # block intersects causal tri
    def _compute():
        q = q_ref[0]                                # (bq, hd)
        k = k_ref[0]                                # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, hd) — heads already folded into batch. Causal only."""
    bh, s, hd = q.shape
    bq, bk = min(bq, s), min(bk, s)
    assert s % bq == 0 and s % bk == 0
    scale = hd ** -0.5
    n_kv = s // bk
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_kv=n_kv, scale=scale),
        grid=(bh, s // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
