"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-channel INT8: returns (q, scale) with x ≈ q * scale.

    ``scale`` has ``axis`` reduced away (one scale per remaining index)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis)


def int8_matmul_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                    x_scale: Optional[jax.Array] = None) -> jax.Array:
    """W8A8 matmul oracle. x: (M, K) float (dynamically quantized if no
    x_scale) or int8; w_q: (K, N) int8; w_scale: (N,)."""
    if x.dtype != jnp.int8:
        x_q, x_scale = quantize_ref(x, axis=-1)
    else:
        x_q = x
        assert x_scale is not None
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    return (acc * x_scale[:, None] * w_scale[None, :]).astype(jnp.bfloat16)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Naive (materialized-scores) MHA oracle. q,k,v: (B, S, H, hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def int8_decode_attention_ref(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                              k_s: jax.Array, v_s: jax.Array,
                              cur_len: jax.Array) -> jax.Array:
    """Decode vs int8 KV cache. q: (B, H, hd); k_q/v_q: (B, S, H, hd) int8;
    k_s/v_s: (B, S, H) f32 scales."""
    kf = k_q.astype(jnp.float32) * k_s[..., None]
    vf = v_q.astype(jnp.float32) * v_s[..., None]
    hd = q.shape[-1]
    s = jnp.einsum("bhd,bchd->bhc", q.astype(jnp.float32), kf) * hd ** -0.5
    mask = jnp.arange(kf.shape[1])[None, None, :] < cur_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhc,bchd->bhd", p, vf).astype(jnp.bfloat16)
