"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def quantize_ref(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-channel INT8: returns (q, scale) with x ≈ q * scale.

    ``scale`` has ``axis`` reduced away (one scale per remaining index)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis)


def int8_matmul_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                    x_scale: Optional[jax.Array] = None) -> jax.Array:
    """W8A8 matmul oracle. x: (M, K) float (dynamically quantized if no
    x_scale) or int8; w_q: (K, N) int8; w_scale: (N,)."""
    if x.dtype != jnp.int8:
        x_q, x_scale = quantize_ref(x, axis=-1)
    else:
        x_q = x
        assert x_scale is not None
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    return (acc * x_scale[:, None] * w_scale[None, :]).astype(jnp.bfloat16)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_offset: int = 0) -> jax.Array:
    """Naive (materialized-scores) MHA oracle. q,k,v: (B, S, H, hd).

    Causality is ABSOLUTE-position: query i sits at position
    ``q_offset + i`` and sees ``kv_pos <= q_offset + i`` — the single
    Sq<Skv convention shared with ``models.attention.flash_attention`` and
    the ``start`` argument of ``cached_attention_ref``
    (``flash_attention_ref(q_offset=o) == cached_attention_ref(start=o)``
    up to dtype staging). The default ``q_offset=0`` makes queries the
    FIRST Sq positions. (This replaces an older ``tril(k=skv-sq)`` mask
    that silently pinned queries to the LAST Sq positions — the opposite of
    what the model's flash path assumed, a drift the prefill kernel would
    otherwise have validated against.)"""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask = jnp.arange(skv)[None, :] <= q_pos[:, None]      # (Sq, Skv)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def cached_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_s: Optional[jax.Array] = None,
                         v_s: Optional[jax.Array] = None,
                         start: jax.Array = None) -> jax.Array:
    """Masked-einsum GQA attention over a slotted KV window — the canonical
    XLA-fallback numerics shared by cache-continuation prefill and the ``xla``
    backend's ``decode_attention`` (bit-identity between the two is what keeps
    engine output token-identical to serial decode).

    q: (B, Sq, Hq, hd) queries at absolute positions ``start..start+Sq-1``;
    k, v: (B, W, Hkv, hd) float, or int8 with ``k_s``/``v_s`` (B, W, Hkv) f32
    scales — for the INT8 cache the per-(pos, head) dequant is fused into the
    score/probability matrices (size B·H·Sq·W) instead of the cache (size
    B·H·W·hd): the cache itself is only ever read as int8. ``start``: (B,)
    int32. W is the visible window: callers guarantee ``W >= start+Sq`` for
    every row whose output is consumed. Returns (B, Sq, Hq, hd) bf16.
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = (q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) * hd ** -0.5
          ).astype(jnp.bfloat16)
    s = jnp.einsum("bqhgd,bchd->bqhgc", qg, k.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    if k_s is not None:
        s = s * jnp.transpose(k_s, (0, 2, 1))[:, None, :, None, :]
    limit = start[:, None] + jnp.arange(sq)[None, :]          # (B, Sq)
    mask = jnp.arange(skv)[None, None, :] <= limit[..., None]  # (B, Sq, W)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_s is not None:
        p = p * jnp.transpose(v_s, (0, 2, 1))[:, None, :, None, :]
    out = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, hd).astype(jnp.bfloat16)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_s: Optional[jax.Array] = None,
                         v_s: Optional[jax.Array] = None,
                         start: jax.Array = None) -> jax.Array:
    """Single-query decode attention (the ``xla`` backend primitive).

    q: (B, Hq, hd); k/v/k_s/v_s/start as ``cached_attention_ref``. Defined as
    exactly the Sq=1 slice of the prefill einsum so decode and chunked
    prefill share one set of numerics bit-for-bit."""
    return cached_attention_ref(q[:, None], k, v, k_s, v_s, start)[:, 0]


# ------------------------------------------------------------------- paged
def _gathered_window(k, v, k_s, v_s, pages):
    """Materialize a paged arena window as contiguous (B, W, ...) views —
    the xla paged read path IS gather + the contiguous einsum, which is what
    pins paged numerics bit-identical to the contiguous layout."""
    from repro.kernels.kv_layout import gather_pages
    g = lambda t: None if t is None else gather_pages(t, pages)
    return g(k), g(v), g(k_s), g(v_s)


def paged_prefill_attention_ref(q, k, v, k_s, v_s, start, pages):
    """q: (B, Sq, Hq, hd); k/v: (n_pages, page_size, Hkv, hd) arenas (int8
    with (n_pages, page_size, Hkv) scales when quantized); pages: (B, n_blk)
    int32 window prefix of each row's page table; start as the contiguous
    primitive."""
    return cached_attention_ref(q, *_gathered_window(k, v, k_s, v_s, pages),
                                start=start)


def paged_decode_attention_ref(q, k, v, k_s, v_s, start, pages):
    """Sq=1 slice of ``paged_prefill_attention_ref`` (q: (B, Hq, hd))."""
    return decode_attention_ref(q, *_gathered_window(k, v, k_s, v_s, pages),
                                start=start)


