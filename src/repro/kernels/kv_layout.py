"""Layout helpers shared by the cache-attention Pallas kernels.

``decode_attention.py`` (Sq=1, split-KV) and ``prefill_attention.py``
(Sq>1, cache continuation) read the same slotted (B, S, Hkv, hd) KV cache
and share the plumbing that is easy to let drift: the jax-version compat
shim for compiler params, the KV-tail block padding, and the INT8 scale
transpose. Keeping these here means a jax rename or a scale-layout fix
lands in both serving hot paths at once.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions (TPUCompilerParams -> CompilerParams)
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def pad_kv_blocks(k: jax.Array, v: jax.Array, k_s: Optional[jax.Array],
                  v_s: Optional[jax.Array], bk: int) -> Tuple:
    """Zero-pad the KV sequence axis (axis 1) to a ``bk`` multiple.

    The padded tail sits at positions beyond any real row's causal limit,
    so the kernels' position masks neutralize it exactly (exp(-inf) = +0.0
    contributions). Returns (k, v, k_s, v_s, n_kv_blocks)."""
    s_len = k.shape[1]
    pk = (-s_len) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        if k_s is not None:
            k_s = jnp.pad(k_s, ((0, 0), (0, pk), (0, 0)))
            v_s = jnp.pad(v_s, ((0, 0), (0, pk), (0, 0)))
    return k, v, k_s, v_s, (s_len + pk) // bk


def transpose_scales(k_s: jax.Array, v_s: jax.Array) -> Tuple:
    """(B, S, Hkv) f32 dequant scales -> (B, Hkv, S): the sequence axis
    lands on lanes, so a (1, 1, bk) block per grid step is contiguous."""
    return jnp.transpose(k_s, (0, 2, 1)), jnp.transpose(v_s, (0, 2, 1))
