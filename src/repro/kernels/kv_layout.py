"""Layout helpers shared by the cache-attention Pallas kernels.

``decode_attention.py`` (Sq=1, split-KV) and ``prefill_attention.py``
(Sq>1, cache continuation) read the same slotted (B, S, Hkv, hd) KV cache
and share the plumbing that is easy to let drift: the jax-version compat
shim for compiler params, the KV-tail block padding, and the INT8 scale
transpose. Keeping these here means a jax rename or a scale-layout fix
lands in both serving hot paths at once.

This module also owns the PAGED layout's logical<->physical index math,
shared by all three backends (DESIGN.md §12). A paged KV arena drops the
slot axis: leaves are (n_pages, page_size, Hkv, hd) and each slot carries a
page table row (max_pages,) of physical page ids, so logical position ``p``
of a slot lives at ``arena[table[p // page_size], p % page_size]``. The
three consumers:

  * ``gather_pages``      — the xla/ref read path: materialize the visible
    window as a contiguous (B, n_blk*page_size, ...) view, then reuse the
    contiguous einsum/kernel verbatim (gathered content == the contiguous
    prefix, so windowed numerics are bit-identical by construction);
  * ``scatter_pages``     — the write path (``models.attention``): flat
    per-element scatter through the same table;
  * the Pallas kernels skip the gather entirely — the KV-block grid axis
    walks the table via scalar-prefetch BlockSpec index maps with the block
    size pinned to ``page_size``, so block j's physical index IS
    ``table[b, j]``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions (TPUCompilerParams -> CompilerParams)
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def pad_kv_blocks(k: jax.Array, v: jax.Array, k_s: Optional[jax.Array],
                  v_s: Optional[jax.Array], bk: int) -> Tuple:
    """Zero-pad the KV sequence axis (axis 1) to a ``bk`` multiple.

    The padded tail sits at positions beyond any real row's causal limit,
    so the kernels' position masks neutralize it exactly (exp(-inf) = +0.0
    contributions). Returns (k, v, k_s, v_s, n_kv_blocks)."""
    s_len = k.shape[1]
    pk = (-s_len) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        if k_s is not None:
            k_s = jnp.pad(k_s, ((0, 0), (0, pk), (0, 0)))
            v_s = jnp.pad(v_s, ((0, 0), (0, pk), (0, 0)))
    return k, v, k_s, v_s, (s_len + pk) // bk


def transpose_scales(k_s: jax.Array, v_s: jax.Array) -> Tuple:
    """(B, S, Hkv) f32 dequant scales -> (B, Hkv, S): the sequence axis
    lands on lanes, so a (1, 1, bk) block per grid step is contiguous."""
    return jnp.transpose(k_s, (0, 2, 1)), jnp.transpose(v_s, (0, 2, 1))


# ------------------------------------------------------------------- paged
def to_store(x: jax.Array, store_dtype) -> jax.Array:
    """Value -> arena storage dtype. A uint16 arena holds raw bfloat16 bit
    patterns (see ``init_kv_cache(paged=True)``): XLA CPU has no native
    bf16 scatter — the float-normalization pass rewrites it through f32
    converts, which materializes a full copy of the arena on EVERY cache
    write (the copy scales with ``total_pages``, not with the tokens
    written). Scatter on uint16 is pure data movement and stays in place
    under donation, so paged arenas store bf16 as raw 16-bit words and
    bitcast at the (small) read/write boundaries — bit patterns are
    untouched, so paged numerics stay bit-identical."""
    if store_dtype == jnp.uint16 and x.dtype != jnp.uint16:
        return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16),
                                            jnp.uint16)
    return x.astype(store_dtype)


def from_store(x: jax.Array) -> jax.Array:
    """Arena storage -> compute value: uint16 bitcasts back to bfloat16,
    every other dtype (bf16 test fixtures, int8 quantized KV) passes
    through untouched."""
    if x.dtype == jnp.uint16:
        return jax.lax.bitcast_convert_type(x, jnp.bfloat16)
    return x


def page_count(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` logical positions (host-side)."""
    return -(-tokens // page_size)


def window_pages(pages: jax.Array, page_size: int,
                 window: Optional[int]) -> jax.Array:
    """Slice a (B, max_pages) table to the (B, n_blk) prefix covering the
    static visible ``window`` (None = every page). The gathered window may
    round up past ``window`` to a page multiple — the extra tail positions
    sit beyond every causal limit and mask to exact zeros, so a page-rounded
    window is bit-identical to the exact one."""
    n_blk = (pages.shape[1] if window is None
             else min(pages.shape[1], page_count(window, page_size)))
    return jax.lax.slice_in_dim(pages, 0, max(n_blk, 1), axis=1)


def gather_pages(leaf: jax.Array, pages: jax.Array) -> jax.Array:
    """Materialize a paged arena's visible window as a contiguous view.

    leaf: (n_pages, page_size, ...) arena; pages: (B, n_blk) int32 physical
    page ids (a ``window_pages`` prefix). Returns (B, n_blk*page_size, ...)
    — exactly what the contiguous layout's first ``n_blk*page_size``
    positions would hold, with unallocated table entries (physical page 0,
    the trash page) contributing garbage only at positions beyond every
    consumer's causal limit."""
    b, n_blk = pages.shape
    g = jnp.take(leaf, pages, axis=0)          # (B, n_blk, page_size, ...)
    return from_store(g.reshape((b, n_blk * leaf.shape[1])
                                + leaf.shape[2:]))


def paged_element_index(pages: jax.Array, pos: jax.Array, sn: int,
                        page_size: int) -> jax.Array:
    """Flat physical element indices for logical positions pos..pos+sn-1.

    pages: (B, max_pages) int32; pos: (B,) int32. Returns (B, sn) int32
    into a ``(n_pages*page_size, ...)``-flattened arena. A negative logical
    position (an inactive row's clamped speculative healing chunk) floors
    into block -1, which the gather clamps to the row's first table entry —
    the engine points inactive rows' tables at the trash page, so the stray
    write lands there."""
    p = pos[:, None] + jnp.arange(sn, dtype=jnp.int32)[None, :]
    blk = jnp.clip(p // page_size, 0, pages.shape[1] - 1)
    phys = jnp.take_along_axis(pages, blk, axis=1)
    return phys * page_size + p % page_size


def scatter_pages(leaf: jax.Array, upd: jax.Array, pages: jax.Array,
                  pos: jax.Array) -> jax.Array:
    """Write (B, sn, ...) ``upd`` at logical positions pos..pos+sn-1 through
    the page table. leaf: (n_pages, page_size, ...) arena (shared across
    rows — distinct slots never map the same writable page, so row scatters
    cannot collide outside the trash page)."""
    n_pages, ps = leaf.shape[:2]
    b, sn = upd.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    idx = paged_element_index(pages, pos, sn, ps).reshape(-1)
    upd = to_store(upd.reshape((b * sn,) + upd.shape[2:]), leaf.dtype)
    flat = leaf.reshape((n_pages * ps,) + leaf.shape[2:])
    return flat.at[idx].set(upd).reshape(leaf.shape)
