"""jit'd public wrappers: dispatch Pallas on TPU, portable jnp elsewhere.

Every op here has a pure-jnp oracle in ``ref.py``; tests sweep shapes/dtypes
with the kernels in interpret mode and assert allclose against the oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize_rowwise(x: jax.Array):
    """(..., K) float -> ((..., K) int8, (...,) f32 scale)."""
    if _on_tpu():
        from repro.kernels.quantize import quantize_rowwise_pallas
        shp = x.shape
        q, s = quantize_rowwise_pallas(x.reshape(-1, shp[-1]))
        return q.reshape(shp), s.reshape(shp[:-1])
    return ref.quantize_ref(x, axis=-1)


def int8_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                x_scale: Optional[jax.Array] = None) -> jax.Array:
    """W8A8 matmul: x (..., K) float (or int8 + x_scale), w_q (K, N) int8.

    Dynamic per-row activation quantization unless x_scale is supplied
    (static calibrated scales from HQP PTQ come through x_scale)."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    if x2.dtype != jnp.int8:
        x_q, x_scale = quantize_rowwise(x2)
    else:
        x_q = x2
        x_scale = x_scale.reshape(-1)
    if _on_tpu():
        from repro.kernels.int8_matmul import int8_matmul_pallas
        out = int8_matmul_pallas(x_q, w_q, x_scale, w_scale)
    else:
        out = ref.int8_matmul_ref(x_q, w_q, w_scale, x_scale)
    return out.reshape(*shp[:-1], w_q.shape[1])


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """(B, S, H, hd) causal MHA (equal q/kv heads; GQA folded by caller)."""
    if _on_tpu():
        from repro.kernels.flash_attention import flash_attention_pallas
        b, s, h, hd = q.shape
        fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, s, hd)
        o = flash_attention_pallas(fold(q), fold(k), fold(v))
        return jnp.moveaxis(o.reshape(b, h, s, hd), 1, 2)
    return ref.flash_attention_ref(q, k, v, causal=True)
