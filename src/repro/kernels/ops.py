"""jit'd public wrappers: shape plumbing + backend-registry dispatch.

The execution backend (``pallas`` | ``xla`` | ``ref``) is resolved per call
site at trace time via ``kernels.backend`` — platform default, overridable
with ``REPRO_BACKEND`` or ``backend.set_backend()``. Every op has a pure-jnp
oracle in ``ref.py``; tests sweep shapes/dtypes with the kernels in interpret
mode and assert allclose against the oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.backend import get_backend
from repro.kernels.kv_layout import from_store, window_pages


def quantize_rowwise(x: jax.Array):
    """(..., K) float -> ((..., K) int8, (...,) f32 scale)."""
    shp = x.shape
    q, s = get_backend().quantize_rowwise(x.reshape(-1, shp[-1]))
    return q.reshape(shp), s.reshape(shp[:-1])


def int8_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                x_scale: Optional[jax.Array] = None) -> jax.Array:
    """W8A8 matmul: x (..., K) float (or int8 + x_scale), w_q (K, N) int8.

    Dynamic per-row activation quantization unless x_scale is supplied
    (static calibrated scales from HQP PTQ come through x_scale)."""
    backend = get_backend()
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    if x2.dtype != jnp.int8:
        x_q, x_scale = backend.quantize_rowwise(x2)
    else:
        x_q = x2
        x_scale = x_scale.reshape(-1)
    out = backend.int8_matmul(x_q, w_q, x_scale, w_scale)
    return out.reshape(*shp[:-1], w_q.shape[1])


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """(B, S, H, hd) causal MHA (equal q/kv heads; GQA folded by caller)."""
    return get_backend().flash_attention(q, k, v)


# ------------------------------------------------------------- KV-cache attn
def _cache_window(cache: dict, window: Optional[int]):
    """Unpack a (possibly INT8) KV-cache dict into (k, v, k_s, v_s) views
    restricted to the first ``window`` positions.

    ``window`` is a STATIC int (or None = full buffer): callers bucket the
    live sequence length up to a block multiple on the host, so the attend
    reads O(window) bytes instead of O(max_seq). Visible-window contract:
    ``window >= start + Sq`` for every row whose output is consumed —
    positions beyond the window would have been masked to exp(-inf) = 0
    exactly, which is why the windowed path is bit-identical to the
    full-mask einsum (the tier-1 regression test).

    A bf16 contiguous cache is *stored* as raw uint16 words (the same
    in-place-write trick as the paged arena — see ``init_kv_cache``);
    ``from_store`` bitcasts the windowed view back to bf16 here, so every
    backend keeps seeing compute-dtype k/v. Slice-then-bitcast is free:
    both are layout ops XLA fuses into the consuming attend."""
    if "k_q" in cache:
        k, v, k_s, v_s = (cache["k_q"], cache["v_q"],
                          cache["k_s"], cache["v_s"])
    else:
        k, v, k_s, v_s = (from_store(cache["k"]), from_store(cache["v"]),
                          None, None)
    if window is not None and window < k.shape[1]:
        sl = lambda t: (None if t is None
                        else jax.lax.slice_in_dim(t, 0, window, axis=1))
        k, v, k_s, v_s = sl(k), sl(v), sl(k_s), sl(v_s)
    return k, v, k_s, v_s


def _paged_window(cache: dict, pages: jax.Array, window: Optional[int]):
    """Unpack a paged arena cache dict into (k, v, k_s, v_s) plus the
    (B, n_blk) page-table prefix covering the static ``window``. Arena
    leaves are (n_pages, page_size, ...) — the n_blk indirection replaces
    the contiguous slice; positions past a row's causal limit (the rounded
    page tail, unallocated trash-page entries) mask to exact zeros, keeping
    the paged read bit-identical to the contiguous one."""
    if "k_q" in cache:
        k, v, k_s, v_s = (cache["k_q"], cache["v_q"],
                          cache["k_s"], cache["v_s"])
    else:
        k, v, k_s, v_s = cache["k"], cache["v"], None, None
    return k, v, k_s, v_s, window_pages(pages, k.shape[1], window)


def cached_attention(q: jax.Array, cache: dict, start: jax.Array,
                     window: Optional[int] = None,
                     pages: Optional[jax.Array] = None) -> jax.Array:
    """Masked-einsum cache attention: q (B, Sq, Hq, hd) at absolute
    positions start..start+Sq-1 vs a cache holding [0, start+Sq). ``start``
    scalar or (B,). NOT backend-dispatched — this einsum (``kernels.ref``)
    is the numerics oracle both the ``decode_attention`` and
    ``prefill_attention`` primitives must match (and IS their ``xla``
    registration); model code routes through those primitives, tests and
    benches call this directly as ground truth. With ``pages`` the cache is
    a paged arena and the oracle is gather + the same einsum."""
    b = q.shape[0]
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,))
    if pages is not None:
        k, v, k_s, v_s, idx = _paged_window(cache, pages, window)
        return ref.paged_prefill_attention_ref(q, k, v, k_s, v_s, start, idx)
    return ref.cached_attention_ref(q, *_cache_window(cache, window),
                                    start=start)


def prefill_attention(q: jax.Array, cache: dict, start: jax.Array,
                      window: Optional[int] = None,
                      pages: Optional[jax.Array] = None) -> jax.Array:
    """Chunked-prefill hot path: a chunk of queries per slot, backend-
    dispatched.

    q: (B, Sq, Hq, hd) at absolute positions start..start+Sq-1 vs a cache
    holding [0, start+Sq); ``start`` scalar or (B,); returns (B, Sq, Hq, hd).
    This wrapper owns cache-dict unpack, the static visible-window slice
    (``window >= start + Sq`` for every consumed row), and start
    broadcasting; ragged-chunk padding to the kernel's query-tile multiple
    lives in the Pallas wrapper (the xla impl — ``cached_attention_ref``
    verbatim — needs none). Sq == 1 is a legal chunk (a prompt's tail): it
    stays on this primitive, NOT ``decode_attention``, so a tail chunk and a
    whole-prompt prefill share bit-identical numerics on every backend.
    ``pages`` (B, max_pages) int32 switches to the paged-arena layout: the
    window becomes a page-table prefix instead of a contiguous slice."""
    b = q.shape[0]
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,))
    if pages is not None:
        k, v, k_s, v_s, idx = _paged_window(cache, pages, window)
        return get_backend().prefill_attention_paged(q, k, v, k_s, v_s,
                                                     start, idx)
    k, v, k_s, v_s = _cache_window(cache, window)
    return get_backend().prefill_attention(q, k, v, k_s, v_s, start)


def decode_attention(q: jax.Array, cache: dict, start: jax.Array,
                     window: Optional[int] = None,
                     pages: Optional[jax.Array] = None) -> jax.Array:
    """Decode hot path: one new query per slot, backend-dispatched.

    q: (B, 1, Hq, hd); ``start`` scalar or (B,) per-slot positions; returns
    (B, 1, Hq, hd). The backend primitive works on the squeezed (B, Hq, hd)
    layout — this wrapper owns the (B, 1, Hq, hd) <-> kernel-layout plumbing
    and the static visible-window slice (a page-table prefix when ``pages``
    marks the cache as a paged arena)."""
    b = q.shape[0]
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32), (b,))
    if pages is not None:
        k, v, k_s, v_s, idx = _paged_window(cache, pages, window)
        return get_backend().decode_attention_paged(q[:, 0], k, v, k_s, v_s,
                                                    start, idx)[:, None]
    k, v, k_s, v_s = _cache_window(cache, window)
    return get_backend().decode_attention(q[:, 0], k, v, k_s, v_s,
                                          start)[:, None]
