"""jit'd public wrappers: shape plumbing + backend-registry dispatch.

The execution backend (``pallas`` | ``xla`` | ``ref``) is resolved per call
site at trace time via ``kernels.backend`` — platform default, overridable
with ``REPRO_BACKEND`` or ``backend.set_backend()``. Every op has a pure-jnp
oracle in ``ref.py``; tests sweep shapes/dtypes with the kernels in interpret
mode and assert allclose against the oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import get_backend


def quantize_rowwise(x: jax.Array):
    """(..., K) float -> ((..., K) int8, (...,) f32 scale)."""
    shp = x.shape
    q, s = get_backend().quantize_rowwise(x.reshape(-1, shp[-1]))
    return q.reshape(shp), s.reshape(shp[:-1])


def int8_matmul(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                x_scale: Optional[jax.Array] = None) -> jax.Array:
    """W8A8 matmul: x (..., K) float (or int8 + x_scale), w_q (K, N) int8.

    Dynamic per-row activation quantization unless x_scale is supplied
    (static calibrated scales from HQP PTQ come through x_scale)."""
    backend = get_backend()
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    if x2.dtype != jnp.int8:
        x_q, x_scale = backend.quantize_rowwise(x2)
    else:
        x_q = x2
        x_scale = x_scale.reshape(-1)
    out = backend.int8_matmul(x_q, w_q, x_scale, w_scale)
    return out.reshape(*shp[:-1], w_q.shape[1])


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """(B, S, H, hd) causal MHA (equal q/kv heads; GQA folded by caller)."""
    return get_backend().flash_attention(q, k, v)
